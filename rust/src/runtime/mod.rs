//! PJRT runtime (DESIGN.md §5 item 10): loads the AOT artifacts
//! (`artifacts/*.hlo.txt` + weight/calib tensor bundles), compiles them on
//! the PJRT CPU client and executes them from the coordinator's hot path.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax >= 0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).  Python never runs at
//! serving time: the weights arrive through `tensor::Bundle` and become
//! PJRT literals once at load.
//!
//! The `xla` crate lives only in the vendored registry of the artifact
//! build image, so execution is gated behind the `pjrt` cargo feature.
//! Without it, an API-identical stub keeps the rest of the stack (manifest
//! inspection, `find`, the software backends, every artifact-free test)
//! building and running; only `Engine::load` / `LoadedModel::run_*` error.

pub mod registry;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{bail, Context, Result};

    use super::registry::{ArtifactMeta, Manifest};
    use crate::tensor::{Bundle, DType};

    /// A compiled model plus its resident parameter literals.
    pub struct LoadedModel {
        pub meta: ArtifactMeta,
        exe: xla::PjRtLoadedExecutable,
        /// Weight + calib literals in the exact parameter order the HLO wants.
        params: Vec<xla::Literal>,
    }

    // The xla crate's handles are raw pointers into the PJRT C API; executions
    // are internally synchronized on the CPU client.  We additionally serialize
    // at the coordinator level (one worker owns one model).
    unsafe impl Send for LoadedModel {}
    unsafe impl Sync for LoadedModel {}

    impl LoadedModel {
        /// Run on f32 input data (images / logits); returns flat f32 output.
        pub fn run_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
            let mut out = vec![0f32; self.output_len()];
            self.run_f32_into(input, &mut out)?;
            Ok(out)
        }

        /// Run on f32 input, writing the flat f32 output into the caller's
        /// buffer (the coordinator's staged per-worker arena).  This is the
        /// Backend-trait-shaped entry point: callers own the output
        /// allocation.  The vendored xla 0.5.1 literal API only exposes
        /// owned-`Vec` extraction, so the PJRT leg still materializes one
        /// transfer vector inside `execute_into` before the copy lands in
        /// `out` — replace with a raw-buffer copy if a later vendored xla
        /// grows one; the call sites are already shaped for it.
        pub fn run_f32_into(&self, input: &[f32], out: &mut [f32]) -> Result<()> {
            let dims: Vec<i64> = self.meta.input_shape.iter().map(|&d| d as i64).collect();
            let expect: usize = self.meta.input_shape.iter().product();
            if input.len() != expect {
                bail!("{}: input len {} != shape {:?}", self.meta.id, input.len(), self.meta.input_shape);
            }
            if out.len() != self.output_len() {
                bail!("{}: output buffer len {} != shape {:?}", self.meta.id, out.len(), self.meta.output_shape);
            }
            let lit = xla::Literal::vec1(input).reshape(&dims)?;
            self.execute_into(lit, out)
        }

        /// Run on i32 input data (token ids).
        pub fn run_i32(&self, input: &[i32]) -> Result<Vec<f32>> {
            let dims: Vec<i64> = self.meta.input_shape.iter().map(|&d| d as i64).collect();
            let expect: usize = self.meta.input_shape.iter().product();
            if input.len() != expect {
                bail!("{}: input len {} != shape {:?}", self.meta.id, input.len(), self.meta.input_shape);
            }
            let lit = xla::Literal::vec1(input).reshape(&dims)?;
            self.execute_with(lit)
        }

        fn execute_with(&self, input: xla::Literal) -> Result<Vec<f32>> {
            let mut args: Vec<&xla::Literal> = self.params.iter().collect();
            args.push(&input);
            let result = self.exe.execute::<&xla::Literal>(&args)?;
            let lit = result[0][0].to_literal_sync()?;
            let out = lit.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }

        fn execute_into(&self, input: xla::Literal, out: &mut [f32]) -> Result<()> {
            let vals = self.execute_with(input)?;
            if vals.len() != out.len() {
                bail!("{}: artifact returned {} f32s, expected {}", self.meta.id, vals.len(), out.len());
            }
            out.copy_from_slice(&vals);
            Ok(())
        }

        pub fn output_len(&self) -> usize {
            self.meta.output_shape.iter().product()
        }

        pub fn batch(&self) -> usize {
            self.meta.batch
        }
    }

    /// The engine: one PJRT CPU client + a cache of compiled models.
    pub struct Engine {
        client: xla::PjRtClient,
        root: PathBuf,
        pub manifest: Manifest,
        cache: Mutex<HashMap<String, std::sync::Arc<LoadedModel>>>,
    }

    unsafe impl Send for Engine {}
    unsafe impl Sync for Engine {}

    impl Engine {
        /// Open the artifacts directory (expects `manifest.json` inside).
        pub fn open(artifacts_dir: &Path) -> Result<Engine> {
            let manifest = Manifest::load(artifacts_dir)
                .with_context(|| format!("loading manifest from {}", artifacts_dir.display()))?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Engine {
                client,
                root: artifacts_dir.to_path_buf(),
                manifest,
                cache: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load (compile + bind weights) one artifact by id; cached.
        pub fn load(&self, id: &str) -> Result<std::sync::Arc<LoadedModel>> {
            if let Some(m) = self.cache.lock().unwrap().get(id) {
                return Ok(m.clone());
            }
            let meta = self
                .manifest
                .get(id)
                .with_context(|| format!("artifact '{id}' not in manifest"))?
                .clone();
            let hlo_path = self.root.join(&meta.hlo);
            let proto = xla::HloModuleProto::from_text_file(&hlo_path)
                .with_context(|| format!("parsing {}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling {id}"))?;

            let params = self.build_params(&meta)?;
            let model = std::sync::Arc::new(LoadedModel { meta, exe, params });
            self.cache.lock().unwrap().insert(id.to_string(), model.clone());
            Ok(model)
        }

        /// Assemble the parameter literals (weights then calib) in manifest
        /// order from the tensor bundles.
        fn build_params(&self, meta: &ArtifactMeta) -> Result<Vec<xla::Literal>> {
            if meta.params.is_empty() {
                return Ok(Vec::new());
            }
            let weights = Bundle::load(&self.root.join(meta.weights.as_ref().context("weights")?))?;
            let calib = match &meta.calib {
                Some(c) if meta.params.iter().any(|p| p.starts_with("calib/")) => {
                    Some(Bundle::load(&self.root.join(c))?)
                }
                _ => None,
            };
            let mut out = Vec::with_capacity(meta.params.len());
            for name in &meta.params {
                let t = if name.starts_with("calib/") {
                    let cb = calib.as_ref().with_context(|| format!("calib bundle for {name}"))?;
                    cb.get(name)?
                } else {
                    weights.get(name)?
                };
                if t.dtype != DType::F32 {
                    bail!("{name}: expected f32 params, got {:?}", t.dtype);
                }
                let vals = t.as_f32()?;
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(&vals).reshape(&dims)?;
                out.push(lit);
            }
            Ok(out)
        }

        /// Artifact ids for a given (model, variant) family.
        pub fn find(&self, model: &str, variant: &str) -> Vec<String> {
            let mut v: Vec<String> = self
                .manifest
                .entries
                .values()
                .filter(|m| m.model.as_deref() == Some(model) && m.variant.as_deref() == Some(variant))
                .map(|m| m.id.clone())
                .collect();
            v.sort();
            v
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use std::path::{Path, PathBuf};

    use anyhow::{Context, Result};

    use super::registry::{ArtifactMeta, Manifest};

    /// Artifact metadata handle; execution requires the `pjrt` feature.
    pub struct LoadedModel {
        pub meta: ArtifactMeta,
    }

    impl LoadedModel {
        pub fn run_f32(&self, _input: &[f32]) -> Result<Vec<f32>> {
            anyhow::bail!(
                "cannot execute artifact '{}': built without the `pjrt` feature \
                 (the xla crate is only vendored in the artifact-build image)",
                self.meta.id
            )
        }

        /// Into-caller-buffer twin of `run_f32` (the Backend hot path);
        /// like every execution entry point it errors without `pjrt`.
        pub fn run_f32_into(&self, _input: &[f32], _out: &mut [f32]) -> Result<()> {
            anyhow::bail!(
                "cannot execute artifact '{}': built without the `pjrt` feature \
                 (the xla crate is only vendored in the artifact-build image)",
                self.meta.id
            )
        }

        pub fn run_i32(&self, _input: &[i32]) -> Result<Vec<f32>> {
            anyhow::bail!(
                "cannot execute artifact '{}': built without the `pjrt` feature \
                 (the xla crate is only vendored in the artifact-build image)",
                self.meta.id
            )
        }

        pub fn output_len(&self) -> usize {
            self.meta.output_shape.iter().product()
        }

        pub fn batch(&self) -> usize {
            self.meta.batch
        }
    }

    /// Manifest-only engine: inspection works, execution errors.
    pub struct Engine {
        root: PathBuf,
        pub manifest: Manifest,
    }

    impl Engine {
        /// Open the artifacts directory (expects `manifest.json` inside).
        pub fn open(artifacts_dir: &Path) -> Result<Engine> {
            let manifest = Manifest::load(artifacts_dir)
                .with_context(|| format!("loading manifest from {}", artifacts_dir.display()))?;
            Ok(Engine { root: artifacts_dir.to_path_buf(), manifest })
        }

        pub fn platform(&self) -> String {
            "stub (build with --features pjrt to execute artifacts)".to_string()
        }

        /// Resolve an artifact id; always errors (no PJRT client available).
        pub fn load(&self, id: &str) -> Result<std::sync::Arc<LoadedModel>> {
            let meta = self
                .manifest
                .get(id)
                .with_context(|| format!("artifact '{id}' not in manifest"))?
                .clone();
            anyhow::bail!(
                "cannot compile artifact '{}' from {}: built without the `pjrt` feature",
                meta.id,
                self.root.display()
            )
        }

        /// Artifact ids for a given (model, variant) family.
        pub fn find(&self, model: &str, variant: &str) -> Vec<String> {
            let mut v: Vec<String> = self
                .manifest
                .entries
                .values()
                .filter(|m| m.model.as_deref() == Some(model) && m.variant.as_deref() == Some(variant))
                .map(|m| m.id.clone())
                .collect();
            v.sort();
            v
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{Engine, LoadedModel};
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::{Engine, LoadedModel};
