//! Artifact registry: parses `artifacts/manifest.json` (written by
//! python/compile/aot.py) into typed metadata.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// One artifact's metadata (a lowered HLO graph + its data dependencies).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub id: String,
    pub hlo: String,
    /// Parameter names in HLO order (weights then calib); empty for op graphs.
    pub params: Vec<String>,
    pub input_dtype: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub batch: usize,
    pub model: Option<String>,
    pub variant: Option<String>,
    pub weights: Option<String>,
    pub calib: Option<String>,
}

/// One exported dataset (tensor bundle with `x` and `y`).
#[derive(Debug, Clone)]
pub struct DatasetMeta {
    pub id: String,
    pub path: String,
    pub n: usize,
}

/// The whole manifest.
#[derive(Debug, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, ArtifactMeta>,
    pub datasets: BTreeMap<String, DatasetMeta>,
}

fn parse_entry(e: &Json) -> Result<ArtifactMeta> {
    let id = e.get_str("id").context("artifact missing id")?.to_string();
    let input = e.get("input").context("missing input")?;
    let output = e.get("output").context("missing output")?;
    let shape = |j: &Json| -> Result<Vec<usize>> {
        Ok(j.get_vec_i64("shape").context("missing shape")?.into_iter().map(|v| v as usize).collect())
    };
    let params = e
        .get("params")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(|p| p.as_str().map(str::to_string)).collect())
        .unwrap_or_default();
    let input_shape = shape(input)?;
    Ok(ArtifactMeta {
        batch: e.get_i64("batch").unwrap_or(input_shape.first().copied().unwrap_or(1) as i64) as usize,
        id,
        hlo: e.get_str("hlo").context("missing hlo")?.to_string(),
        params,
        input_dtype: input.get_str("dtype").unwrap_or("f32").to_string(),
        input_shape,
        output_shape: shape(output)?,
        model: e.get_str("model").map(str::to_string),
        variant: e.get_str("variant").map(str::to_string),
        weights: e.get_str("weights").map(str::to_string),
        calib: e.get_str("calib").map(str::to_string),
    })
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let root = json::parse(&text).context("parsing manifest.json")?;
        let mut out = Manifest::default();
        for key in ["models", "ops"] {
            if let Some(arr) = root.get(key).and_then(Json::as_arr) {
                for e in arr {
                    let meta = parse_entry(e)?;
                    out.entries.insert(meta.id.clone(), meta);
                }
            }
        }
        if let Some(arr) = root.get("datasets").and_then(Json::as_arr) {
            for e in arr {
                let id = e.get_str("id").context("dataset id")?.to_string();
                out.datasets.insert(
                    id.clone(),
                    DatasetMeta {
                        id,
                        path: e.get_str("path").context("dataset path")?.to_string(),
                        n: e.get_i64("n").unwrap_or(0) as usize,
                    },
                );
            }
        }
        Ok(out)
    }

    pub fn get(&self, id: &str) -> Option<&ArtifactMeta> {
        self.entries.get(id)
    }

    /// All distinct model names with lowered accuracy artifacts.
    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .entries
            .values()
            .filter_map(|m| m.model.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("sole-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"models": [{"id": "m_fp32_b4", "hlo": "m.hlo.txt", "model": "m",
                 "variant": "fp32", "batch": 4, "params": ["w1", "calib/a/alpha"],
                 "weights": "weights/m", "calib": "calib/m",
                 "input": {"dtype": "f32", "shape": [4, 8]},
                 "output": {"dtype": "f32", "shape": [4, 2]}}],
                "ops": [{"id": "op_x", "hlo": "op.hlo.txt", "params": [],
                 "input": {"dtype": "f32", "shape": [2, 2]},
                 "output": {"dtype": "f32", "shape": [2, 2]}}],
                "datasets": [{"id": "d", "path": "data/d", "n": 7}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 2);
        let a = m.get("m_fp32_b4").unwrap();
        assert_eq!(a.batch, 4);
        assert_eq!(a.params.len(), 2);
        assert_eq!(a.input_shape, vec![4, 8]);
        assert_eq!(m.datasets["d"].n, 7);
        assert_eq!(m.models(), vec!["m"]);
        // op entries default batch from the leading input dim
        assert_eq!(m.get("op_x").unwrap().batch, 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
