//! Artifact registry: parses `artifacts/manifest.json` (written by
//! python/compile/aot.py) into typed metadata.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// One artifact's metadata (a lowered HLO graph + its data dependencies).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub id: String,
    pub hlo: String,
    /// Parameter names in HLO order (weights then calib); empty for op graphs.
    pub params: Vec<String>,
    pub input_dtype: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub batch: usize,
    pub model: Option<String>,
    pub variant: Option<String>,
    pub weights: Option<String>,
    pub calib: Option<String>,
}

/// One lowered (model, variant) artifact family: the bucketed artifacts
/// the serving layer loads as one `PjrtBackend` and registers as one
/// router service (service discovery for the PJRT path).
#[derive(Debug, Clone)]
pub struct Family {
    pub model: String,
    pub variant: String,
    /// Artifact ids, ascending by lowered batch size.
    pub ids: Vec<String>,
    /// Lowered batch sizes (the serving buckets), ascending.
    pub buckets: Vec<usize>,
    /// Flat f32 length of one item (input shape beyond the batch dim).
    pub item_len: usize,
}

impl Family {
    /// The router service name this family registers under.
    pub fn service_name(&self) -> String {
        format!("{}/{}", self.model, self.variant)
    }
}

/// One exported dataset (tensor bundle with `x` and `y`).
#[derive(Debug, Clone)]
pub struct DatasetMeta {
    pub id: String,
    pub path: String,
    pub n: usize,
}

/// The whole manifest.
#[derive(Debug, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, ArtifactMeta>,
    pub datasets: BTreeMap<String, DatasetMeta>,
}

fn parse_entry(e: &Json) -> Result<ArtifactMeta> {
    let id = e.get_str("id").context("artifact missing id")?.to_string();
    let input = e.get("input").context("missing input")?;
    let output = e.get("output").context("missing output")?;
    let shape = |j: &Json| -> Result<Vec<usize>> {
        Ok(j.get_vec_i64("shape").context("missing shape")?.into_iter().map(|v| v as usize).collect())
    };
    let params = e
        .get("params")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(|p| p.as_str().map(str::to_string)).collect())
        .unwrap_or_default();
    let input_shape = shape(input)?;
    Ok(ArtifactMeta {
        batch: e.get_i64("batch").unwrap_or(input_shape.first().copied().unwrap_or(1) as i64) as usize,
        id,
        hlo: e.get_str("hlo").context("missing hlo")?.to_string(),
        params,
        input_dtype: input.get_str("dtype").unwrap_or("f32").to_string(),
        input_shape,
        output_shape: shape(output)?,
        model: e.get_str("model").map(str::to_string),
        variant: e.get_str("variant").map(str::to_string),
        weights: e.get_str("weights").map(str::to_string),
        calib: e.get_str("calib").map(str::to_string),
    })
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let root = json::parse(&text).context("parsing manifest.json")?;
        let mut out = Manifest::default();
        for key in ["models", "ops"] {
            if let Some(arr) = root.get(key).and_then(Json::as_arr) {
                for e in arr {
                    let meta = parse_entry(e)?;
                    out.entries.insert(meta.id.clone(), meta);
                }
            }
        }
        if let Some(arr) = root.get("datasets").and_then(Json::as_arr) {
            for e in arr {
                let id = e.get_str("id").context("dataset id")?.to_string();
                out.datasets.insert(
                    id.clone(),
                    DatasetMeta {
                        id,
                        path: e.get_str("path").context("dataset path")?.to_string(),
                        n: e.get_i64("n").unwrap_or(0) as usize,
                    },
                );
            }
        }
        Ok(out)
    }

    pub fn get(&self, id: &str) -> Option<&ArtifactMeta> {
        self.entries.get(id)
    }

    /// Group the model artifacts into (model, variant) families — every
    /// service the manifest can back, with its bucket sizes ascending.
    /// Op graphs (no model/variant) are not families; they stay reachable
    /// by id.
    pub fn families(&self) -> Vec<Family> {
        let mut groups: BTreeMap<(String, String), Vec<&ArtifactMeta>> = BTreeMap::new();
        for m in self.entries.values() {
            if let (Some(model), Some(variant)) = (&m.model, &m.variant) {
                groups.entry((model.clone(), variant.clone())).or_default().push(m);
            }
        }
        groups
            .into_iter()
            .map(|((model, variant), mut metas)| {
                metas.sort_by_key(|m| m.batch);
                Family {
                    item_len: metas[0].input_shape.iter().skip(1).product(),
                    ids: metas.iter().map(|m| m.id.clone()).collect(),
                    buckets: metas.iter().map(|m| m.batch).collect(),
                    model,
                    variant,
                }
            })
            .collect()
    }

    /// All distinct model names with lowered accuracy artifacts.
    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .entries
            .values()
            .filter_map(|m| m.model.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("sole-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"models": [{"id": "m_fp32_b4", "hlo": "m.hlo.txt", "model": "m",
                 "variant": "fp32", "batch": 4, "params": ["w1", "calib/a/alpha"],
                 "weights": "weights/m", "calib": "calib/m",
                 "input": {"dtype": "f32", "shape": [4, 8]},
                 "output": {"dtype": "f32", "shape": [4, 2]}}],
                "ops": [{"id": "op_x", "hlo": "op.hlo.txt", "params": [],
                 "input": {"dtype": "f32", "shape": [2, 2]},
                 "output": {"dtype": "f32", "shape": [2, 2]}}],
                "datasets": [{"id": "d", "path": "data/d", "n": 7}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 2);
        let a = m.get("m_fp32_b4").unwrap();
        assert_eq!(a.batch, 4);
        assert_eq!(a.params.len(), 2);
        assert_eq!(a.input_shape, vec![4, 8]);
        assert_eq!(m.datasets["d"].n, 7);
        assert_eq!(m.models(), vec!["m"]);
        // op entries default batch from the leading input dim
        assert_eq!(m.get("op_x").unwrap().batch, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn families_group_bucketed_artifacts() {
        let dir = std::env::temp_dir().join(format!("sole-families-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"models": [
                 {"id": "m_fp32_b8", "hlo": "a.hlo.txt", "model": "m", "variant": "fp32",
                  "batch": 8, "input": {"shape": [8, 12]}, "output": {"shape": [8, 2]}},
                 {"id": "m_fp32_b1", "hlo": "b.hlo.txt", "model": "m", "variant": "fp32",
                  "batch": 1, "input": {"shape": [1, 12]}, "output": {"shape": [1, 2]}},
                 {"id": "m_sole_b4", "hlo": "c.hlo.txt", "model": "m", "variant": "sole",
                  "batch": 4, "input": {"shape": [4, 12]}, "output": {"shape": [4, 2]}}],
                "ops": [{"id": "op_x", "hlo": "op.hlo.txt",
                 "input": {"shape": [2, 2]}, "output": {"shape": [2, 2]}}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let fams = m.families();
        // two families, sorted by (model, variant); op graphs excluded
        assert_eq!(fams.len(), 2);
        assert_eq!(fams[0].service_name(), "m/fp32");
        assert_eq!(fams[0].buckets, vec![1, 8]); // ascending by batch
        assert_eq!(fams[0].ids, vec!["m_fp32_b1", "m_fp32_b8"]);
        assert_eq!(fams[0].item_len, 12);
        assert_eq!(fams[1].service_name(), "m/sole");
        assert_eq!(fams[1].buckets, vec![4]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
