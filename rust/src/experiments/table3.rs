//! Table III: energy-efficiency and area-efficiency of the SOLE units vs
//! Softermax (softmax), NN-LUT (layernorm) and the GPU — subunits and
//! complete units, at the paper's operating point (32 lanes, 1 GHz,
//! L=785 softmax rows / C=192 layernorm rows from DeiT-T@448).

use crate::hw::gpu;
use crate::hw::units::{AiLayerNormUnit, E2SoftmaxUnit, HwUnit, NnLutLayerNormUnit, SoftermaxUnit};
use crate::model::latency::SOLE_UNITS;
use crate::model::PaperModel;
use crate::util::json::{obj, Json};

use super::{render_table, ExperimentOut};

pub fn run() -> ExperimentOut {
    let l_sm = 785;
    let c_ln = 192;
    let sole_sm = E2SoftmaxUnit::default();
    let soft = SoftermaxUnit::default();
    let sole_ln = AiLayerNormUnit::default();
    let nnlut = NnLutLayerNormUnit::default();

    // energy per processed element (pJ) and area (um^2)
    let e_sm_sole = sole_sm.energy_per_row(l_sm);
    let e_sm_soft = soft.energy_per_row(l_sm);
    let e_ln_sole = sole_ln.energy_per_row(c_ln);
    let e_ln_nn = nnlut.energy_per_row(c_ln);
    let a_sm_sole = sole_sm.area();
    let a_sm_soft = soft.area();
    let a_ln_sole = sole_ln.area();
    let a_ln_nn = nnlut.area();

    // subunit rows (paper convention: Normalization = softmax stage 2,
    // Statistic = layernorm stage 1)
    let norm_e = e_sm_soft.stage2 / e_sm_sole.stage2;
    let norm_a = a_sm_soft.stage2 / a_sm_sole.stage2;
    let stat_e = e_ln_nn.stage1 / e_ln_sole.stage1;
    let stat_a = a_ln_nn.stage1 / a_ln_sole.stage1;
    let full_sm_e = e_sm_soft.total() / e_sm_sole.total();
    let full_sm_a = a_sm_soft.total() / a_sm_sole.total();
    let full_ln_e = e_ln_nn.total() / e_ln_sole.total();
    let full_ln_a = a_ln_nn.total() / a_ln_sole.total();

    // GPU energy-efficiency: joules per element over the DeiT-T workload
    let m = PaperModel::deit("deit_t", 192, 3);
    let batch = 8;
    let (mut g_sm_j, mut s_sm_j, mut elems_sm) = (0f64, 0f64, 0f64);
    for w in m.softmax_work(batch) {
        g_sm_j += gpu::energy_j(gpu::softmax_time(w.rows, w.len)) * w.kernels as f64;
        s_sm_j += sole_sm.energy_j(w.rows, w.len) * w.kernels as f64 * SOLE_UNITS as f64 / SOLE_UNITS as f64;
        elems_sm += (w.rows * w.len * w.kernels) as f64;
    }
    let (mut g_ln_j, mut s_ln_j) = (0f64, 0f64);
    for w in m.layernorm_work(batch) {
        g_ln_j += gpu::energy_j(gpu::layernorm_time(w.rows, w.len)) * w.kernels as f64;
        s_ln_j += sole_ln.energy_j(w.rows, w.len) * w.kernels as f64;
    }
    let gpu_sm_ratio = g_sm_j / s_sm_j;
    let gpu_ln_ratio = g_ln_j / s_ln_j;
    let _ = elems_sm;

    let fx = |v: f64| format!("{v:.2}x");
    let rows = vec![
        vec!["Softermax".into(), "Normalization Unit".into(), fx(norm_e), fx(norm_a),
             "2.46x / 2.89x".into()],
        vec!["Softermax".into(), "Softmax Unit".into(), fx(full_sm_e), fx(full_sm_a),
             "3.04x / 2.82x".into()],
        vec!["NN-LUT".into(), "Statistic Unit".into(), fx(stat_e), fx(stat_a),
             "11.3x / 3.79x".into()],
        vec!["NN-LUT".into(), "LayerNorm Unit".into(), fx(full_ln_e), fx(full_ln_a),
             "3.86x / 3.32x".into()],
        vec!["2080Ti GPU".into(), "Softmax Unit".into(), format!("{gpu_sm_ratio:.0}x"), "-".into(),
             "4925x / -".into()],
        vec!["2080Ti GPU".into(), "LayerNorm Unit".into(), format!("{gpu_ln_ratio:.0}x"), "-".into(),
             "4259x / -".into()],
    ];
    let text = render_table(
        "Table III — SOLE vs Softermax / NN-LUT / GPU (energy- & area-efficiency)",
        &["baseline".into(), "unit".into(), "energy-eff".into(), "area-eff".into(),
          "paper (E / A)".into()],
        &rows,
    ) + &format!(
        "\nabsolute SOLE numbers at this operating point:\n\
         E2Softmax Unit:   {:.0} um^2, {:.3} pJ/elem, {:.1} mW\n\
         AILayerNorm Unit: {:.0} um^2, {:.3} pJ/elem, {:.1} mW\n",
        a_sm_sole.total(),
        e_sm_sole.total() / l_sm as f64,
        sole_sm.power_mw(l_sm),
        a_ln_sole.total(),
        e_ln_sole.total() / c_ln as f64,
        sole_ln.power_mw(c_ln),
    );

    ExperimentOut {
        name: "table3",
        text,
        json: obj(vec![
            ("normalization_energy", Json::Num(norm_e)),
            ("normalization_area", Json::Num(norm_a)),
            ("softmax_unit_energy", Json::Num(full_sm_e)),
            ("softmax_unit_area", Json::Num(full_sm_a)),
            ("statistic_energy", Json::Num(stat_e)),
            ("statistic_area", Json::Num(stat_a)),
            ("layernorm_unit_energy", Json::Num(full_ln_e)),
            ("layernorm_unit_area", Json::Num(full_ln_a)),
            ("gpu_softmax_energy", Json::Num(gpu_sm_ratio)),
            ("gpu_layernorm_energy", Json::Num(gpu_ln_ratio)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn ratios_in_paper_ballpark() {
        let out = super::run();
        let g = |k: &str| out.json.get_f64(k).unwrap();
        // who-wins and rough factors must hold (DESIGN.md §2)
        assert!(g("softmax_unit_energy") > 1.8 && g("softmax_unit_energy") < 6.0);
        assert!(g("softmax_unit_area") > 1.5 && g("softmax_unit_area") < 6.0);
        assert!(g("layernorm_unit_energy") > 2.0 && g("layernorm_unit_energy") < 8.0);
        assert!(g("layernorm_unit_area") > 1.8 && g("layernorm_unit_area") < 8.0);
        assert!(g("statistic_energy") > 4.0, "INT32-mult kill is the headline");
        // GPU: orders of magnitude
        assert!(g("gpu_softmax_energy") > 500.0);
        assert!(g("gpu_layernorm_energy") > 500.0);
    }
}
