//! §III-C numeric claim: dynamic compression induces ~0.2% error on E(x^2)
//! and ~0.4% on sigma for uniformly distributed inputs — Monte-Carlo over
//! the bit-exact implementation, plus the same sweep for Gaussian inputs
//! (the distribution LayerNorm actually sees) as an extension.

use crate::layernorm::compress::compressed_square;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

use super::{render_table, ExperimentOut};

fn sweep(dist: &str, n: usize, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let (mut se, mut sr, mut sx) = (0f64, 0f64, 0f64);
    for _ in 0..n {
        let x = match dist {
            "uniform" => rng.range_i64(0, 256) as u8,
            _ => (rng.normal().abs() * 48.0).min(255.0) as u8, // half-normal codes
        };
        se += (x as f64) * (x as f64);
        sr += (compressed_square(x) << 4) as f64;
        sx += x as f64;
    }
    let (ex2, rx2, ex) = (se / n as f64, sr / n as f64, sx / n as f64);
    let e_ex2 = (rx2 - ex2).abs() / ex2.max(1e-9);
    let sd_t = (ex2 - ex * ex).max(0.0).sqrt();
    let sd_r = (rx2 - ex * ex).max(0.0).sqrt();
    let e_sd = (sd_r - sd_t).abs() / sd_t.max(1e-9);
    (e_ex2, e_sd)
}

pub fn run() -> ExperimentOut {
    let n = 400_000;
    let (u_ex2, u_sd) = sweep("uniform", n, 21);
    let (g_ex2, g_sd) = sweep("gaussian", n, 22);
    let rows = vec![
        vec!["uniform u8 (paper's setting)".into(),
             format!("{:.2}%", u_ex2 * 100.0), format!("{:.2}%", u_sd * 100.0),
             "0.2% / 0.4%".into()],
        vec!["half-normal codes (LN-realistic)".into(),
             format!("{:.2}%", g_ex2 * 100.0), format!("{:.2}%", g_sd * 100.0),
             "- (extension)".into()],
    ];
    let text = render_table(
        "§III-C — dynamic compression error on E(x^2) and sigma",
        &["input distribution".into(), "E(x^2) err".into(), "sigma err".into(), "paper".into()],
        &rows,
    );
    ExperimentOut {
        name: "compress_error",
        text,
        json: obj(vec![
            ("uniform_ex2", Json::Num(u_ex2)),
            ("uniform_sigma", Json::Num(u_sd)),
            ("gaussian_ex2", Json::Num(g_ex2)),
            ("gaussian_sigma", Json::Num(g_sd)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn uniform_errors_match_paper_order() {
        let out = super::run();
        assert!(out.json.get_f64("uniform_ex2").unwrap() < 0.01);
        assert!(out.json.get_f64("uniform_sigma").unwrap() < 0.015);
    }
}
