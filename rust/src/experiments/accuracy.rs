//! Tables I & II: accuracy of FP32 / FP32+SOLE / INT8 / INT8+SOLE, measured
//! by running the AOT artifacts through the PJRT runtime on the exported
//! eval sets — the Rust serving stack evaluating its own models, no Python.

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::Engine;
use crate::tensor::Bundle;
use crate::util::json::{obj, Json};

use super::{render_table, ExperimentOut};

pub const VARIANTS: [&str; 4] = ["fp32", "fp32_sole", "int8", "int8_sole"];

/// Evaluate one (model, variant) over up to `max_samples` of its eval set.
pub fn eval_model(
    engine: &Engine,
    artifacts: &Path,
    model: &str,
    variant: &str,
    max_samples: usize,
) -> Result<f64> {
    let ids = engine.find(model, variant);
    let id = ids
        .iter()
        .find(|i| i.ends_with("_b64"))
        .or(ids.first())
        .with_context(|| format!("no artifact for {model}/{variant}"))?;
    let m = engine.load(id)?;
    let dataset = if model.starts_with("bert_") {
        format!("data/{model}_eval")
    } else {
        "data/cv_eval".to_string()
    };
    let data = Bundle::load(&artifacts.join(dataset))?;
    let x = data.get("x")?;
    let y = data.get("y")?.as_i32()?;
    let item: usize = x.shape[1..].iter().product();
    let b = m.batch();
    let ncls = m.meta.output_shape[1];
    let n = (y.len().min(max_samples) / b) * b;
    anyhow::ensure!(n > 0, "eval set smaller than one batch");
    let mut correct = 0usize;
    if m.meta.input_dtype == "i32" {
        let xs = x.as_i32()?;
        for bi in 0..n / b {
            let logits = m.run_i32(&xs[bi * b * item..(bi + 1) * b * item])?;
            correct += count_correct(&logits, &y[bi * b..(bi + 1) * b], ncls);
        }
    } else {
        let xs = x.as_f32()?;
        for bi in 0..n / b {
            let logits = m.run_f32(&xs[bi * b * item..(bi + 1) * b * item])?;
            correct += count_correct(&logits, &y[bi * b..(bi + 1) * b], ncls);
        }
    }
    Ok(correct as f64 / n as f64)
}

fn count_correct(logits: &[f32], labels: &[i32], ncls: usize) -> usize {
    labels
        .iter()
        .enumerate()
        .filter(|(i, &lab)| {
            let row = &logits[i * ncls..(i + 1) * ncls];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            pred as i32 == lab
        })
        .count()
}

/// Render one accuracy table over `models` (Table I: CV; Table II: NLP).
pub fn run_table(
    name: &'static str,
    title: &str,
    engine: &Engine,
    artifacts: &Path,
    models: &[String],
    max_samples: usize,
) -> Result<ExperimentOut> {
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    let mut drops = Vec::new();
    for model in models {
        let mut cells = vec![model.clone()];
        let mut accs = Vec::new();
        for v in VARIANTS {
            let acc = eval_model(engine, artifacts, model, v, max_samples)?;
            cells.push(format!("{:.2}%", acc * 100.0));
            accs.push(acc);
        }
        drops.push((accs[0] - accs[1]) * 100.0);
        drops.push((accs[2] - accs[3]) * 100.0);
        jrows.push(obj(vec![
            ("model", Json::Str(model.clone())),
            ("fp32", Json::Num(accs[0])),
            ("fp32_sole", Json::Num(accs[1])),
            ("int8", Json::Num(accs[2])),
            ("int8_sole", Json::Num(accs[3])),
        ]));
        rows.push(cells);
    }
    let avg_drop = drops.iter().sum::<f64>() / drops.len().max(1) as f64;
    let worst = drops.iter().cloned().fold(f64::MIN, f64::max);
    let text = render_table(
        title,
        &["model".into(), "FP32".into(), "FP32+SOLE".into(), "INT8".into(), "INT8+SOLE".into()],
        &rows,
    ) + &format!(
        "\nSOLE accuracy drop: avg {avg_drop:.2}pp, worst {worst:.2}pp \
         (paper: avg 0.38/0.2pp, worst 0.9/0.8pp) — no retraining anywhere\n"
    );
    Ok(ExperimentOut {
        name,
        text,
        json: obj(vec![
            ("rows", Json::Arr(jrows)),
            ("avg_drop_pp", Json::Num(avg_drop)),
            ("worst_drop_pp", Json::Num(worst)),
        ]),
    })
}

/// Table I (CV surrogates).
pub fn table1(engine: &Engine, artifacts: &Path, max_samples: usize) -> Result<ExperimentOut> {
    let models: Vec<String> = engine
        .manifest
        .models()
        .into_iter()
        .filter(|m| !m.starts_with("bert_"))
        .collect();
    run_table(
        "table1",
        "Table I — CV accuracy (synthetic-shapes surrogates of DeiT/Swin)",
        engine,
        artifacts,
        &models,
        max_samples,
    )
}

/// Table II (NLP surrogates).
pub fn table2(engine: &Engine, artifacts: &Path, max_samples: usize) -> Result<ExperimentOut> {
    let models: Vec<String> = engine
        .manifest
        .models()
        .into_iter()
        .filter(|m| m.starts_with("bert_"))
        .collect();
    run_table(
        "table2",
        "Table II — NLP accuracy (synthetic GLUE/SQuAD analogues, BERT surrogate)",
        engine,
        artifacts,
        &models,
        max_samples,
    )
}
