//! Experiment generators — one per table/figure of the paper (DESIGN.md §4).
//!
//! Each generator prints the paper-style table to stdout and returns a
//! `Json` blob that the CLI writes under `artifacts/results/` so
//! EXPERIMENTS.md can quote exact numbers.

pub mod ablation;
pub mod accuracy;
pub mod compress_error;
pub mod fig1;
pub mod fig3;
pub mod fig6;
pub mod table3;

use crate::util::json::Json;

/// A rendered experiment: the human table plus machine-readable results.
pub struct ExperimentOut {
    pub name: &'static str,
    pub text: String,
    pub json: Json,
}

impl ExperimentOut {
    pub fn print(&self) {
        println!("{}", self.text);
    }

    /// Write the JSON blob under `<artifacts>/results/<name>.json`.
    pub fn save(&self, artifacts: &std::path::Path) -> anyhow::Result<()> {
        let dir = artifacts.join("results");
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join(format!("{}.json", self.name)), self.json.to_string_compact())?;
        Ok(())
    }
}

/// Simple fixed-width table renderer shared by the generators.
pub fn render_table(title: &str, header: &[String], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, cell) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8) + 2))
            .collect::<String>()
    };
    let mut out = format!("\n== {title} ==\n");
    out.push_str(&line(header));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().map(|w| w + 2).sum::<usize>()));
    out.push('\n');
    for r in rows {
        out.push_str(&line(r));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            "T",
            &["a".into(), "bb".into()],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("== T =="));
        assert!(t.contains("333"));
    }
}
