//! Fig 1(a): latency breakdown of DeiT-Tiny (448x448, 785 tokens) on the
//! GPU model, FP32 vs INT8 — the motivation figure: quantizing matmuls
//! inflates the Softmax/LayerNorm share.

use crate::model::latency::{latency, ExecMode};
use crate::model::PaperModel;
use crate::util::json::{obj, Json};

use super::{render_table, ExperimentOut};

pub fn run(batch: usize) -> ExperimentOut {
    let m = PaperModel::deit("deit_t", 192, 3);
    let f = latency(&m, batch, ExecMode::Fp32Gpu);
    let i = latency(&m, batch, ExecMode::Int8Gpu);

    let pct = |x: f64, t: f64| format!("{:.1}%", 100.0 * x / t);
    let rows = vec![
        vec![
            "FP32".to_string(),
            format!("{:.2}", f.total() * 1e3),
            pct(f.matmul, f.total()),
            pct(f.softmax, f.total()),
            pct(f.layernorm, f.total()),
            pct(f.elementwise, f.total()),
        ],
        vec![
            "INT8".to_string(),
            format!("{:.2}", i.total() * 1e3),
            pct(i.matmul, i.total()),
            pct(i.softmax, i.total()),
            pct(i.layernorm, i.total()),
            pct(i.elementwise, i.total()),
        ],
    ];
    let text = render_table(
        &format!("Fig 1(a) — DeiT-T@448 latency breakdown on 2080Ti model (batch {batch})"),
        &["mode".into(), "total ms".into(), "matmul".into(), "softmax".into(),
          "layernorm".into(), "elementwise".into()],
        &rows,
    ) + &format!(
        "\npaper's observation reproduced: Softmax+LN share grows {:.0}% -> {:.0}% under INT8\n",
        100.0 * f.nonlinear_share(),
        100.0 * i.nonlinear_share()
    );

    let series = |b: &crate::model::latency::Breakdown| {
        obj(vec![
            ("matmul", Json::Num(b.matmul)),
            ("softmax", Json::Num(b.softmax)),
            ("layernorm", Json::Num(b.layernorm)),
            ("elementwise", Json::Num(b.elementwise)),
        ])
    };
    ExperimentOut {
        name: "fig1a",
        text,
        json: obj(vec![
            ("batch", Json::Int(batch as i64)),
            ("fp32", series(&f)),
            ("int8", series(&i)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn produces_growing_share() {
        let out = super::run(8);
        assert!(out.text.contains("Fig 1(a)"));
        let f = out.json.get("fp32").unwrap();
        let i = out.json.get("int8").unwrap();
        let share = |b: &crate::util::json::Json| {
            let s = b.get_f64("softmax").unwrap() + b.get_f64("layernorm").unwrap();
            let t = s + b.get_f64("matmul").unwrap() + b.get_f64("elementwise").unwrap();
            s / t
        };
        assert!(share(i) > share(f));
    }
}
