//! Fig 6: (a) standalone Softmax/LayerNorm speedup of 32 SOLE units over
//! the GPU model, batch 1..16 on DeiT-T@448; (b) end-to-end speedup and
//! latency breakdown (FP32 / INT8 / INT8+SOLE).

use crate::model::latency::{latency, layernorm_gpu_vs_sole, softmax_gpu_vs_sole, ExecMode};
use crate::model::PaperModel;
use crate::util::json::{arr_f64, obj, Json};

use super::{render_table, ExperimentOut};

pub fn run_a(batches: &[usize]) -> ExperimentOut {
    let m = PaperModel::deit("deit_t", 192, 3);
    let mut rows = Vec::new();
    let mut sm_sp = Vec::new();
    let mut ln_sp = Vec::new();
    for &b in batches {
        let (gs, ss) = softmax_gpu_vs_sole(&m, b);
        let (gl, sl) = layernorm_gpu_vs_sole(&m, b);
        sm_sp.push(gs / ss);
        ln_sp.push(gl / sl);
        rows.push(vec![
            b.to_string(),
            format!("{:.0}us", gs * 1e6),
            format!("{:.1}us", ss * 1e6),
            format!("{:.1}x", gs / ss),
            format!("{:.0}us", gl * 1e6),
            format!("{:.1}us", sl * 1e6),
            format!("{:.1}x", gl / sl),
        ]);
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let text = render_table(
        "Fig 6(a) — Softmax / LayerNorm speedup over GPU (DeiT-T@448, 32 SOLE units)",
        &["batch".into(), "gpu sm".into(), "sole sm".into(), "speedup".into(),
          "gpu ln".into(), "sole ln".into(), "speedup".into()],
        &rows,
    ) + &format!(
        "\naverage speedup: softmax {:.1}x (paper 36.2x, range 29.3-57.5x), \
         layernorm {:.1}x (paper 61.3x, range 38.4-86.8x)\n",
        avg(&sm_sp),
        avg(&ln_sp)
    );
    ExperimentOut {
        name: "fig6a",
        text,
        json: obj(vec![
            ("batches", Json::Arr(batches.iter().map(|&b| Json::Int(b as i64)).collect())),
            ("softmax_speedup", arr_f64(&sm_sp)),
            ("layernorm_speedup", arr_f64(&ln_sp)),
            ("softmax_avg", Json::Num(avg(&sm_sp))),
            ("layernorm_avg", Json::Num(avg(&ln_sp))),
        ]),
    }
}

pub fn run_b(batches: &[usize]) -> ExperimentOut {
    let m = PaperModel::deit("deit_t", 192, 3);
    let mut rows = Vec::new();
    let mut int8_sp = Vec::new();
    let mut sole_sp = Vec::new();
    for &b in batches {
        let f = latency(&m, b, ExecMode::Fp32Gpu);
        let i = latency(&m, b, ExecMode::Int8Gpu);
        let s = latency(&m, b, ExecMode::Int8Sole);
        int8_sp.push(f.total() / i.total());
        sole_sp.push(f.total() / s.total());
        rows.push(vec![
            b.to_string(),
            format!("{:.2}ms", f.total() * 1e3),
            format!("{:.2}ms ({:.2}x)", i.total() * 1e3, f.total() / i.total()),
            format!("{:.2}ms ({:.2}x)", s.total() * 1e3, f.total() / s.total()),
            format!("{:.0}%", 100.0 * i.nonlinear_share()),
            format!("{:.1}%", 100.0 * s.nonlinear_share()),
        ]);
    }
    let text = render_table(
        "Fig 6(b) — end-to-end DeiT-T@448: FP32 vs INT8 vs INT8+SOLE",
        &["batch".into(), "fp32".into(), "int8".into(), "int8+sole".into(),
          "int8 nl-share".into(), "sole nl-share".into()],
        &rows,
    ) + "\npaper bands: INT8 1.10-1.28x, INT8+SOLE 1.50-2.09x\n";
    ExperimentOut {
        name: "fig6b",
        text,
        json: obj(vec![
            ("batches", Json::Arr(batches.iter().map(|&b| Json::Int(b as i64)).collect())),
            ("int8_speedup", arr_f64(&int8_sp)),
            ("sole_speedup", arr_f64(&sole_sp)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig6a_speedups_order_of_magnitude() {
        let out = super::run_a(&[1, 4, 16]);
        let sm = out.json.get_vec_f64("softmax_speedup").unwrap();
        assert!(sm.iter().all(|&s| s > 10.0 && s < 100.0), "{sm:?}");
        // the paper's trend: speedup grows with batch (GPU spills L2)
        assert!(sm.last().unwrap() > sm.first().unwrap());
    }

    #[test]
    fn fig6b_sole_beats_int8() {
        let out = super::run_b(&[8]);
        let i = out.json.get_vec_f64("int8_speedup").unwrap()[0];
        let s = out.json.get_vec_f64("sole_speedup").unwrap()[0];
        assert!(s > i && i > 1.0);
    }
}
