//! Design-choice ablations (DESIGN.md §4 "ablation benches") — the knobs
//! the paper fixed, swept over the bit-exact software models:
//!
//!  * softmax input scale exponent `e` (the paper uses 2^-4),
//!  * lane count / chunking of the online pass (1 = Algorithm 1 verbatim,
//!    32 = the shipped unit) — does slice-wise max referencing cost
//!    accuracy?
//!  * AILayerNorm PTF `alpha_max` (0 = plain per-tensor quantization — the
//!    inter-channel-variation failure PTF exists to fix).
//!
//! Error metric: mean/max absolute error vs the exact op over Gaussian
//! workloads with transformer-realistic statistics.

use crate::layernorm::ai::{layernorm_exact, AiLayerNorm};
use crate::softmax::e2::softmax_exact;
use crate::softmax::{E2Softmax, E2SoftmaxConfig};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

use super::{render_table, ExperimentOut};

fn softmax_err(e: u32, chunk: usize, rows: usize, l: usize, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let sm = E2Softmax::new(E2SoftmaxConfig { e, chunk });
    let (mut mean, mut worst, mut n) = (0.0f64, 0.0f64, 0.0f64);
    for _ in 0..rows {
        let x: Vec<f32> = (0..l).map(|_| (rng.normal() * 2.0) as f32).collect();
        let approx = sm.forward_logits(&x);
        let exact = softmax_exact(&x);
        for (a, b) in approx.iter().zip(&exact) {
            let d = (a - b).abs();
            mean += d;
            worst = worst.max(d);
            n += 1.0;
        }
    }
    (mean / n, worst)
}

fn layernorm_err(alpha_max: u8, rows: usize, c: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let ln = AiLayerNorm::default();
    // transformer-realistic: a few channels carry 8x outliers (the
    // inter-channel variation PTF targets)
    let chan_scale: Vec<f64> =
        (0..c).map(|i| if i % 17 == 0 { 8.0 } else { 1.0 }).collect();
    let mut rms_err = 0.0f64;
    let mut rms_sig = 0.0f64;
    for r in 0..rows {
        let x: Vec<f32> = (0..c).map(|i| (rng.normal() * chan_scale[i]) as f32).collect();
        // PTF fit on this row family
        let rmax: Vec<f64> = chan_scale.iter().map(|&s| s * 4.0).collect();
        let base = 4.0;
        let alpha: Vec<u8> = rmax
            .iter()
            .map(|&v| ((v / base).log2().round()).clamp(0.0, alpha_max as f64) as u8)
            .collect();
        let s = rmax
            .iter()
            .zip(&alpha)
            .map(|(&v, &a)| v / 2f64.powi(a as i32))
            .fold(0.0, f64::max)
            / 127.0;
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let approx = ln.forward_real(&x, &alpha, s, &gamma, &beta);
        let exact = layernorm_exact(&x, &gamma, &beta, 1e-9);
        for (a, b) in approx.iter().zip(&exact) {
            rms_err += (a - b) * (a - b);
            rms_sig += b * b;
        }
        let _ = r;
    }
    (rms_err / rms_sig).sqrt()
}

pub fn run() -> ExperimentOut {
    // --- softmax: input scale exponent -----------------------------------
    let mut rows_tbl = Vec::new();
    let mut e_errs = Vec::new();
    for e in [2u32, 3, 4, 5, 6] {
        let (mean, worst) = softmax_err(e, 32, 64, 128, 7);
        e_errs.push((e, mean, worst));
        rows_tbl.push(vec![
            format!("2^-{e}"),
            format!("{:.4}", mean),
            format!("{:.3}", worst),
            if e == 4 { "<- paper".into() } else { String::new() },
        ]);
    }
    let t1 = render_table(
        "Ablation A — E2Softmax input scale (mean/max abs err vs exact, L=128)",
        &["scale".into(), "mean err".into(), "max err".into(), "".into()],
        &rows_tbl,
    );

    // --- softmax: chunk width --------------------------------------------
    let mut rows_tbl = Vec::new();
    let mut c_errs = Vec::new();
    for chunk in [1usize, 8, 32, 128] {
        let (mean, worst) = softmax_err(4, chunk, 64, 128, 8);
        c_errs.push((chunk, mean));
        rows_tbl.push(vec![
            chunk.to_string(),
            format!("{:.4}", mean),
            format!("{:.3}", worst),
            if chunk == 32 { "<- the unit's vector size".into() } else { String::new() },
        ]);
    }
    let t2 = render_table(
        "Ablation B — online-pass slice width (accuracy cost of slice-max referencing)",
        &["chunk".into(), "mean err".into(), "max err".into(), "".into()],
        &rows_tbl,
    );

    // --- layernorm: PTF alpha_max ----------------------------------------
    let mut rows_tbl = Vec::new();
    let mut a_errs = Vec::new();
    for amax in [0u8, 1, 3, 5, 7] {
        let rel = layernorm_err(amax, 48, 192, 9);
        a_errs.push((amax, rel));
        rows_tbl.push(vec![
            amax.to_string(),
            format!("{:.2}%", rel * 100.0),
            if amax == 0 { "plain per-tensor (no PTF)".into() } else { String::new() },
        ]);
    }
    let t3 = render_table(
        "Ablation C — AILayerNorm PTF alpha_max (rel RMS err vs exact, outlier channels)",
        &["alpha_max".into(), "rel rms err".into(), "".into()],
        &rows_tbl,
    );

    let text = format!(
        "{t1}{t2}{t3}\nfindings: (A) e=4 sits at the knee — coarser scales saturate the\n\
         4-bit code range, finer ones clip the dynamic range; (B) the 32-lane\n\
         slice referencing is accuracy-free vs Algorithm-1 (chunk=1), which is\n\
         why the hardware can take the lane-parallel shortcut; (C) PTF is the\n\
         load-bearing piece for outlier channels — alpha_max=0 is several times\n\
         worse, and the curve flattens by alpha_max~5 (the calibrator's cap).\n"
    );

    ExperimentOut {
        name: "ablation",
        text,
        json: obj(vec![
            (
                "softmax_e",
                Json::Arr(
                    e_errs
                        .iter()
                        .map(|&(e, m, w)| {
                            obj(vec![
                                ("e", Json::Int(e as i64)),
                                ("mean", Json::Num(m)),
                                ("worst", Json::Num(w)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "softmax_chunk",
                Json::Arr(
                    c_errs
                        .iter()
                        .map(|&(c, m)| {
                            obj(vec![("chunk", Json::Int(c as i64)), ("mean", Json::Num(m))])
                        })
                        .collect(),
                ),
            ),
            (
                "ptf_alpha_max",
                Json::Arr(
                    a_errs
                        .iter()
                        .map(|&(a, r)| {
                            obj(vec![("alpha_max", Json::Int(a as i64)), ("rel_rms", Json::Num(r))])
                        })
                        .collect(),
                ),
            ),
        ]),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn paper_operating_points_are_good_choices() {
        let out = super::run();
        // (A) e=4 no worse than 2x the best mean error
        let es = out.json.get("softmax_e").unwrap().as_arr().unwrap().to_vec();
        let best = es.iter().map(|e| e.get_f64("mean").unwrap()).fold(f64::MAX, f64::min);
        let at4 = es
            .iter()
            .find(|e| e.get_i64("e").unwrap() == 4)
            .unwrap()
            .get_f64("mean")
            .unwrap();
        assert!(at4 <= 2.0 * best, "e=4 mean {at4} vs best {best}");
        // (B) chunk=32 within 25% of chunk=1
        let cs = out.json.get("softmax_chunk").unwrap().as_arr().unwrap().to_vec();
        let m1 = cs.iter().find(|c| c.get_i64("chunk").unwrap() == 1).unwrap().get_f64("mean").unwrap();
        let m32 = cs.iter().find(|c| c.get_i64("chunk").unwrap() == 32).unwrap().get_f64("mean").unwrap();
        assert!(m32 <= 1.25 * m1 + 1e-6, "chunk32 {m32} vs chunk1 {m1}");
        // (C) PTF off is strictly worse than PTF at the calibrator's cap
        let ps = out.json.get("ptf_alpha_max").unwrap().as_arr().unwrap().to_vec();
        let a0 = ps.iter().find(|p| p.get_i64("alpha_max").unwrap() == 0).unwrap().get_f64("rel_rms").unwrap();
        let a5 = ps.iter().find(|p| p.get_i64("alpha_max").unwrap() == 5).unwrap().get_f64("rel_rms").unwrap();
        assert!(a0 > 1.5 * a5, "PTF should matter: a0={a0} a5={a5}");
    }
}
