//! Fig 3: distribution of exp(X_i - X_max) in the log2 domain, measured on
//! real attention logits captured at calibration time (artifacts/fig3.json).
//! Renders an ASCII histogram and checks the "close to normal on a log2
//! scale" observation that justifies log2 quantization.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, obj, Json};

use super::ExperimentOut;

pub fn run(artifacts: &Path) -> Result<ExperimentOut> {
    let text = std::fs::read_to_string(artifacts.join("fig3.json"))
        .context("fig3.json missing — run `make artifacts`")?;
    let doc = json::parse(&text)?;
    let hist = doc.get_vec_f64("hist").context("hist")?;
    let edges = doc.get_vec_f64("edges").context("edges")?;
    let mean = doc.get_f64("mean").unwrap_or(0.0);
    let std = doc.get_f64("std").unwrap_or(0.0);
    let frac_below = doc.get_f64("frac_below_kmax").unwrap_or(0.0);
    let count = doc.get_f64("count").unwrap_or(0.0);

    let maxc = hist.iter().cloned().fold(1.0, f64::max);
    let mut out = String::from("\n== Fig 3 — distribution of exp(Xi - Xmax) in log2 domain ==\n");
    out.push_str(&format!(
        "attention logits from the trained ViT: n={count:.0}  mean={mean:.2}  std={std:.2}\n"
    ));
    for (i, &c) in hist.iter().enumerate() {
        let lo = edges[i];
        let bars = ((c / maxc) * 56.0).round() as usize;
        out.push_str(&format!("{lo:7.1} | {}{}\n", "#".repeat(bars), if c > 0.0 && bars == 0 { "." } else { "" }));
    }
    out.push_str(&format!(
        "\nmass below the 4-bit clip point (log2 < -15): {:.2}% — the paper's\n\
         k=15 saturation throws away a negligible tail; the bulk sits within\n\
         ~2 sigma of the mode like the paper's Fig 3.\n",
        frac_below * 100.0
    ));

    Ok(ExperimentOut {
        name: "fig3",
        text: out,
        json: obj(vec![
            ("mean", Json::Num(mean)),
            ("std", Json::Num(std)),
            ("frac_below_kmax", Json::Num(frac_below)),
            ("hist", json::arr_f64(&hist)),
            ("edges", json::arr_f64(&edges)),
        ]),
    })
}
