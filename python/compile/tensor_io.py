"""Shared tensor container format (python writer <-> rust reader).

A *bundle* is ``<name>.json`` + ``<name>.bin``: the JSON manifest lists the
tensors (name, dtype, shape, byte offset, byte length) and the .bin file
holds their raw little-endian data back to back.  Deliberately trivial so
the Rust ``tensor/`` module can parse it with the in-tree JSON substrate —
no npz/protobuf dependency on either side.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

_DTYPES = {
    np.dtype(np.float32): "f32",
    np.dtype(np.int32): "i32",
    np.dtype(np.uint8): "u8",
    np.dtype(np.int64): "i64",
}
_NP_FROM = {"f32": np.float32, "i32": np.int32, "u8": np.uint8, "i64": np.int64}


def write_bundle(path_stem: Path, tensors: dict[str, np.ndarray]) -> None:
    """Write ``{stem}.json`` + ``{stem}.bin`` for an ordered dict of arrays."""
    path_stem.parent.mkdir(parents=True, exist_ok=True)
    entries = []
    blob = bytearray()
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype == np.float64:
            arr = arr.astype(np.float32)
        if arr.dtype == np.int64:
            arr = arr.astype(np.int32)
        dt = _DTYPES.get(arr.dtype)
        if dt is None:
            raise TypeError(f"unsupported dtype {arr.dtype} for {name}")
        raw = arr.tobytes()  # C-order little-endian on all supported hosts
        entries.append({
            "name": name,
            "dtype": dt,
            "shape": list(arr.shape),
            "offset": len(blob),
            "nbytes": len(raw),
        })
        blob.extend(raw)
    manifest = {"version": 1, "tensors": entries, "total_bytes": len(blob)}
    Path(f"{path_stem}.json").write_text(json.dumps(manifest))
    Path(f"{path_stem}.bin").write_bytes(bytes(blob))


def read_bundle(path_stem: Path) -> dict[str, np.ndarray]:
    manifest = json.loads(Path(f"{path_stem}.json").read_text())
    blob = Path(f"{path_stem}.bin").read_bytes()
    out: dict[str, np.ndarray] = {}
    for e in manifest["tensors"]:
        arr = np.frombuffer(
            blob, dtype=_NP_FROM[e["dtype"]], count=int(np.prod(e["shape"], initial=1)),
            offset=e["offset"],
        ).reshape(e["shape"])
        out[e["name"]] = arr.copy()
    return out


def bundle_exists(path_stem: Path) -> bool:
    return Path(f"{path_stem}.json").exists() and Path(f"{path_stem}.bin").exists()
