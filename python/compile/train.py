"""Build-time trainer (exact ops only — SOLE is post-training, per the paper).

Minimal Adam + cross-entropy on the synthetic datasets.  Trained weights are
cached under ``artifacts/weights/<name>.npz`` so ``make artifacts`` is a
no-op when nothing changed.
"""

from __future__ import annotations

import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import tensor_io
from .model import EXACT, ModelConfig, Params, forward, init_params


def _tree_map2(f, a, b):
    return jax.tree_util.tree_map(f, a, b)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits: np.ndarray, labels: np.ndarray) -> float:
    return float((np.asarray(logits).argmax(-1) == np.asarray(labels)).mean())


def train_model(
    cfg: ModelConfig,
    x_train: np.ndarray,
    y_train: np.ndarray,
    *,
    steps: int = 800,
    batch: int = 64,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 200,
    log=print,
) -> Params:
    """Train ``cfg`` with Adam; returns the trained params pytree."""
    params = init_params(cfg, seed=seed)
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    def loss_fn(p, xb, yb):
        return cross_entropy(forward(p, xb, cfg, EXACT), yb)

    @jax.jit
    def step(p, m, v, t, xb, yb):
        loss, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        m = _tree_map2(lambda mi, gi: b1 * mi + (1 - b1) * gi, m, g)
        v = _tree_map2(lambda vi, gi: b2 * vi + (1 - b2) * gi * gi, v, g)
        corr1 = 1 - b1 ** t
        corr2 = 1 - b2 ** t
        p = _tree_map2(lambda pi, mi_vi: pi - lr * mi_vi, p,
                       _tree_map2(lambda mi, vi: (mi / corr1) / (jnp.sqrt(vi / corr2) + eps), m, v))
        return p, m, v, loss

    rng = np.random.default_rng(seed)
    n = len(x_train)
    t0 = time.time()
    for t in range(1, steps + 1):
        idx = rng.integers(0, n, size=batch)
        xb = jnp.asarray(x_train[idx])
        yb = jnp.asarray(y_train[idx])
        params, m, v, loss = step(params, m, v, t, xb, yb)
        if t % log_every == 0 or t == 1:
            log(f"    step {t:5d}  loss {float(loss):.4f}  ({time.time()-t0:.1f}s)")
    return params


# ---------------------------------------------------------------------------
# npz (de)serialization of the params pytree
# ---------------------------------------------------------------------------

def _flatten(params: Params, prefix: str = "") -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    if isinstance(params, dict):
        for k, val in params.items():
            out.update(_flatten(val, f"{prefix}{k}/"))
    elif isinstance(params, list):
        for i, val in enumerate(params):
            out.update(_flatten(val, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(params)
    return out


def _unflatten(flat: dict[str, np.ndarray]) -> Params:
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(val)

    def listify(node):
        if isinstance(node, dict):
            if node and all(k.isdigit() for k in node):
                return [listify(node[str(i)]) for i in range(len(node))]
            return {k: listify(v) for k, v in node.items()}
        return node

    return listify(root)


def save_params(stem: Path, params: Params) -> None:
    tensor_io.write_bundle(stem, _flatten(params))


def load_params(stem: Path) -> Params:
    return _unflatten(tensor_io.read_bundle(stem))


def train_or_load(
    name: str,
    cfg: ModelConfig,
    x_train: np.ndarray,
    y_train: np.ndarray,
    weights_dir: Path,
    *,
    steps: int,
    seed: int = 0,
    batch: int = 64,
    log=print,
) -> Params:
    path = weights_dir / name
    if tensor_io.bundle_exists(path):
        log(f"  [{name}] cached weights {path}")
        return load_params(path)
    log(f"  [{name}] training ({steps} steps)...")
    params = train_model(cfg, x_train, y_train, steps=steps, seed=seed, batch=batch, log=log)
    save_params(path, params)
    return params
