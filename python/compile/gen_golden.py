"""Golden-vector emitter: pins the Rust bit-exact models to ref.py.

Writes JSON files under artifacts/golden/ with inputs and every staged
intermediate from the integer references.  The Rust test
``rust/tests/golden_vectors.rs`` replays them and asserts exact equality.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from .kernels import ref


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def gen_log2exp(path: Path) -> None:
    cases = []
    for e in (3, 4, 5):
        for d in range(0, -256, -1):
            cases.append({"d": d, "e": e, "k": ref.log2exp_int(d, e)})
    path.write_text(json.dumps({"cases": cases}))


def gen_aldivision(path: Path) -> None:
    rng = _rng(7)
    cases = []
    for _ in range(512):
        k_y = int(rng.integers(0, 31))
        sum_q15 = int(rng.integers(1 << 15, 1 << 26))
        o23, o8 = ref.aldivision_int(k_y, sum_q15)
        cases.append({"k_y": k_y, "sum_q15": sum_q15, "out_q23": o23, "out_u8": o8})
    path.write_text(json.dumps({"cases": cases}))


def gen_e2softmax(path: Path) -> None:
    rng = _rng(11)
    cases = []
    for chunk in (1, 32):
        for n in (1, 7, 32, 96, 256):
            for _ in range(4):
                x = rng.normal(0, 2.0, n)
                q = np.clip(np.round((x - x.max()) * 16), -255, 0).astype(int)
                gold = ref.e2softmax_online_int(q, e=4, chunk=chunk)
                cases.append({
                    "q": q.tolist(), "e": 4, "chunk": chunk,
                    "k": gold["k"], "sum_q15": gold["sum_q15"],
                    "out_q23": gold["out_q23"], "out_u8": gold["out_u8"],
                })
    path.write_text(json.dumps({"cases": cases}))


def gen_compress(path: Path) -> None:
    cases = []
    for x in range(256):
        y, s = ref.dynamic_compress_int(x)
        cases.append({"x": x, "y": y, "s": s})
    path.write_text(json.dumps({"cases": cases}))


def gen_ailayernorm(path: Path) -> None:
    rng = _rng(13)
    cases = []
    for c in (16, 64, 192):
        for _ in range(6):
            codes = rng.integers(0, 256, size=c).astype(int)
            alpha = rng.integers(0, 4, size=c).astype(int)
            gamma = rng.normal(1.0, 0.2, c)
            beta = rng.normal(0.0, 0.2, c)
            gold = ref.ailayernorm_int(codes, alpha, 128, gamma, beta)
            cases.append({
                "codes": codes.tolist(), "alpha": alpha.tolist(), "zp": 128,
                "gamma": gamma.tolist(), "beta": beta.tolist(),
                "ex": gold["ex"], "ex2": gold["ex2"],
                "std_inv": gold["std_inv"],
                "y": list(map(float, gold["y"])),
            })
    path.write_text(json.dumps({"cases": cases}))


def gen_rsqrt(path: Path) -> None:
    rng = _rng(17)
    cases = []
    for _ in range(256):
        num = int(rng.integers(1, 1 << 40))
        den = int(rng.integers(1, 1 << 20))
        cases.append({"num": num, "den": den, "out": ref.rsqrt_hw(num, den)})
    path.write_text(json.dumps({"cases": cases, "lut": ref.rsqrt_lut()}))


def generate_all(golden_dir: Path, log=print) -> None:
    golden_dir.mkdir(parents=True, exist_ok=True)
    gen_log2exp(golden_dir / "log2exp.json")
    gen_aldivision(golden_dir / "aldivision.json")
    gen_e2softmax(golden_dir / "e2softmax.json")
    gen_compress(golden_dir / "compress.json")
    gen_ailayernorm(golden_dir / "ailayernorm.json")
    gen_rsqrt(golden_dir / "rsqrt.json")
    log(f"  golden vectors -> {golden_dir}")
