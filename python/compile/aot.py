"""AOT driver: trains, calibrates, and lowers every model variant to HLO text.

This is the single entry point of the build-time Python stack
(``make artifacts`` -> ``python -m compile.aot --artifacts ../artifacts``).
It is idempotent: every stage skips itself when its outputs already exist.

Stages
  1. datasets      synthetic CV + NLP train/eval splits; eval exported as
                   tensor bundles for the Rust harness
  2. train         one tiny model per Table I/II row (cached weight bundles)
  3. calibrate     PTF (alpha/s/zp) per LayerNorm + Fig 3 statistics
  4. accuracy_py   python-side accuracy matrix (incl. Softermax / I-BERT
                   ablations) — cross-checks the Rust PJRT evaluation
  5. lower         HLO text per (architecture x variant x batch); weights
                   stay runtime *parameters* (loaded by rust/src/tensor),
                   so each architecture lowers once — not once per task
  6. golden        bit-exact test vectors for the Rust models
  7. manifest      artifacts/manifest.json describing everything above

Interchange is HLO *text*: jax >= 0.5 serialized protos carry 64-bit ids
that xla_extension 0.5.1 rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import calibrate, data, gen_golden, tensor_io, train
from .model import (
    EXACT,
    MODEL_ZOO,
    ModelConfig,
    OpsConfig,
    bert_for_task,
    forward,
)
from .kernels import ailayernorm as ail_kernel
from .kernels import e2softmax as e2_kernel

# ---------------------------------------------------------------------------
# Build plan
# ---------------------------------------------------------------------------

CV_MODELS = ["deit_t", "deit_s", "swin_t"]
NLP_TASKS = data.NLP_TASKS
VARIANTS = ["fp32", "fp32_sole", "int8", "int8_sole"]
EVAL_BATCH = 64
SERVING_BATCHES = [1, 4, 8, 16]
CV_TRAIN_N, CV_EVAL_N = 2048, 512
NLP_TRAIN_N, NLP_EVAL_N = 2048, 512
CV_STEPS = 300
NLP_STEPS = 150
TRAIN_BATCH = 48


def ops_for(variant: str, cfg: ModelConfig, ln_calib: dict | None) -> OpsConfig:
    v = 16 if cfg.kind == "swin" else 32
    mm = "int8" if variant.startswith("int8") else "fp32"
    if variant.endswith("sole"):
        return OpsConfig(softmax="sole", layernorm="sole", matmul=mm,
                         softmax_v=v, ln_calib=ln_calib)
    return OpsConfig(matmul=mm)


def ln_names(cfg: ModelConfig) -> list[str]:
    names = []
    for i in range(cfg.depth):
        names += [f"b{i}.ln1", f"b{i}.ln2"]
    return names + ["lnf"]


# ---------------------------------------------------------------------------
# HLO lowering with weights (and PTF calib) as runtime parameters
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default printer elides any
    # sizeable constant to "{...}", which the downstream HLO text parser
    # silently reads back as zeros (cost us a debugging session: the
    # AILayerNorm rsqrt LUT became all-zero inside the artifacts).
    return comp.as_hlo_text(print_large_constants=True)


def flat_weight_items(params) -> list[tuple[str, np.ndarray]]:
    flat = train._flatten(params)
    return [(k, np.asarray(v, dtype=np.float32)) for k, v in sorted(flat.items())]


def calib_items(cfg: ModelConfig, ln_calib: dict) -> list[tuple[str, np.ndarray]]:
    items: list[tuple[str, np.ndarray]] = []
    for name in ln_names(cfg):
        cal = ln_calib[name]
        items.append((f"calib/{name}/alpha", np.asarray(cal["alpha"], dtype=np.float32)))
        items.append((f"calib/{name}/s", np.asarray([cal["s"]], dtype=np.float32)))
    return items


def make_infer_fn(cfg: ModelConfig, variant: str, weight_names: list[str],
                  calib_names: list[str]):
    """Build fn(weights_list, calib_list, x) -> logits for lowering."""

    def fn(weights_list, calib_list, x):
        flat = dict(zip(weight_names, weights_list))
        params = train._unflatten(flat)
        ln_calib = None
        if calib_names:
            ln_calib = {}
            for name, arr in zip(calib_names, calib_list):
                _, ln, field = name.split("/")
                entry = ln_calib.setdefault(ln, {"zp": 128})
                entry[field] = arr if field == "alpha" else arr[0]
        ops = ops_for(variant, cfg, ln_calib)
        return (forward(params, x, cfg, ops),)

    return fn


def lower_model(cfg: ModelConfig, params, variant: str, ln_calib: dict | None,
                batch: int, out_path: Path) -> dict:
    """Lower one (model, variant, batch) to HLO text; returns its manifest."""
    witems = flat_weight_items(params)
    wnames = [k for k, _ in witems]
    citems = calib_items(cfg, ln_calib) if variant.endswith("sole") else []
    cnames = [k for k, _ in citems]
    fn = make_infer_fn(cfg, variant, wnames, cnames)

    wspecs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for _, a in witems]
    cspecs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for _, a in citems]
    if cfg.kind == "bert":
        xspec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
        input_desc = {"dtype": "i32", "shape": [batch, cfg.seq_len]}
    else:
        xspec = jax.ShapeDtypeStruct((batch, cfg.img_size, cfg.img_size, 1), jnp.float32)
        input_desc = {"dtype": "f32", "shape": [batch, cfg.img_size, cfg.img_size, 1]}

    lowered = jax.jit(fn).lower(wspecs, cspecs, xspec)
    text = to_hlo_text(lowered)
    out_path.write_text(text)
    return {
        "hlo": out_path.name,
        "params": wnames + cnames,
        "input": input_desc,
        "output": {"dtype": "f32", "shape": [batch, cfg.n_classes]},
        "batch": batch,
        "variant": variant,
    }


def lower_op_kernels(art: Path, log) -> list[dict]:
    """Standalone op graphs for runtime tests + microbenches."""
    out = []
    rows, length = 64, 128
    cdim = 64

    def emit(name, fn, specs, input_desc, output_desc):
        p = art / f"op_{name}.hlo.txt"
        if not p.exists():
            text = to_hlo_text(jax.jit(fn).lower(*specs))
            p.write_text(text)
            log(f"  lowered {p.name}")
        out.append({"id": f"op_{name}", "kind": "op", "hlo": p.name,
                    "params": [], "input": input_desc, "output": output_desc})

    emit("e2softmax",
         lambda x: (e2_kernel.e2softmax(x)[0],),
         [jax.ShapeDtypeStruct((rows, length), jnp.float32)],
         {"dtype": "f32", "shape": [rows, length]},
         {"dtype": "f32", "shape": [rows, length]})
    emit("softmax_exact",
         lambda x: (jax.nn.softmax(x, axis=-1),),
         [jax.ShapeDtypeStruct((rows, length), jnp.float32)],
         {"dtype": "f32", "shape": [rows, length]},
         {"dtype": "f32", "shape": [rows, length]})

    alpha = jnp.zeros(cdim)
    gamma = jnp.ones(cdim)
    beta = jnp.zeros(cdim)

    emit("ailayernorm",
         lambda codes: (ail_kernel.ailayernorm(codes, alpha, gamma, beta, zp=128),),
         [jax.ShapeDtypeStruct((rows, cdim), jnp.float32)],
         {"dtype": "f32", "shape": [rows, cdim]},
         {"dtype": "f32", "shape": [rows, cdim]})
    emit("layernorm_exact",
         lambda x: ((x - jnp.mean(x, -1, keepdims=True))
                    / jnp.sqrt(jnp.var(x, -1, keepdims=True) + 1e-6),),
         [jax.ShapeDtypeStruct((rows, cdim), jnp.float32)],
         {"dtype": "f32", "shape": [rows, cdim]},
         {"dtype": "f32", "shape": [rows, cdim]})
    return out


# ---------------------------------------------------------------------------
# Python-side accuracy matrix (stage 4)
# ---------------------------------------------------------------------------

def eval_accuracy(cfg, params, x_eval, y_eval, ops: OpsConfig, batch=128) -> float:
    correct = 0
    fwd = jax.jit(lambda xb: forward(params, xb, cfg, ops))
    for i in range(0, len(x_eval), batch):
        xb = jnp.asarray(x_eval[i:i + batch])
        logits = np.asarray(fwd(xb))
        correct += int((logits.argmax(-1) == y_eval[i:i + batch]).sum())
    return correct / len(x_eval)


def accuracy_variants(cfg, params, x_eval, y_eval, ln_calib) -> dict[str, float]:
    """The four Table I/II variants + prior-work ablations (jnp twins)."""
    out = {}
    for variant in VARIANTS:
        ops = ops_for(variant, cfg, ln_calib)
        ops = dataclasses.replace(ops, use_pallas=False)
        out[variant] = eval_accuracy(cfg, params, x_eval, y_eval, ops)
    # ablations: prior-work approximations under fp32 matmul
    out["fp32_softermax"] = eval_accuracy(
        cfg, params, x_eval, y_eval, OpsConfig(softmax="softermax"))
    out["fp32_ibert"] = eval_accuracy(
        cfg, params, x_eval, y_eval, OpsConfig(softmax="ibert", layernorm="ibert"))
    return out


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--skip-serving", action="store_true")
    args = ap.parse_args()
    art = Path(args.artifacts).resolve()
    art.mkdir(parents=True, exist_ok=True)
    (art / "data").mkdir(exist_ok=True)
    (art / "weights").mkdir(exist_ok=True)
    (art / "calib").mkdir(exist_ok=True)
    (art / "golden").mkdir(exist_ok=True)
    log = print
    t_start = time.time()

    manifest: dict = {"version": 1, "models": [], "ops": [], "datasets": []}

    # ---- stage 1: datasets ---------------------------------------------
    log("[1/7] datasets")
    cv_train = data.shapes_dataset(CV_TRAIN_N, seed=100)
    cv_eval = data.shapes_dataset(CV_EVAL_N, seed=200)
    if not tensor_io.bundle_exists(art / "data" / "cv_eval"):
        tensor_io.write_bundle(art / "data" / "cv_eval",
                               {"x": cv_eval[0], "y": cv_eval[1]})
    manifest["datasets"].append({"id": "cv_eval", "n": CV_EVAL_N,
                                 "path": "data/cv_eval"})
    nlp_data = {}
    for task in NLP_TASKS:
        tr = data.tokens_dataset(task, NLP_TRAIN_N, seed=300)
        ev = data.tokens_dataset(task, NLP_EVAL_N, seed=400)
        nlp_data[task] = (tr, ev)
        if not tensor_io.bundle_exists(art / "data" / f"bert_{task}_eval"):
            tensor_io.write_bundle(art / "data" / f"bert_{task}_eval",
                                   {"x": ev[0], "y": ev[1]})
        manifest["datasets"].append({"id": f"bert_{task}_eval", "n": NLP_EVAL_N,
                                     "path": f"data/bert_{task}_eval"})

    # ---- stage 2+3+4+5 per model ----------------------------------------
    accuracy_table: dict[str, dict] = {}
    fig3: dict = {}

    def build_model(name: str, cfg: ModelConfig, train_xy, eval_xy, steps, seed):
        log(f"[model {name}]")
        params = train.train_or_load(name, cfg, train_xy[0], train_xy[1],
                                     art / "weights", steps=steps, seed=seed, batch=TRAIN_BATCH, log=log)
        calib_path = art / "calib" / f"{name}_ptf.json"
        if calib_path.exists():
            ln_calib = json.loads(calib_path.read_text())
        else:
            ln_calib = calibrate.ptf_calibrate(params, jnp.asarray(train_xy[0][:64]), cfg)
            calib_path.write_text(json.dumps(ln_calib))
        # calib bundle for rust
        if not tensor_io.bundle_exists(art / "calib" / name):
            tensor_io.write_bundle(art / "calib" / name,
                                   dict(calib_items(cfg, ln_calib)))
        # fig3 stats from the first CV model
        if cfg.kind != "bert" and "hist" not in fig3:
            fig3.update(calibrate.softmax_input_stats(
                params, jnp.asarray(train_xy[0][:16]), cfg))
        # accuracy matrix (python side)
        acc_path = art / f"accuracy_{name}.json"
        if acc_path.exists():
            accuracy_table[name] = json.loads(acc_path.read_text())
        else:
            accuracy_table[name] = accuracy_variants(
                cfg, params, eval_xy[0], eval_xy[1], ln_calib)
            acc_path.write_text(json.dumps(accuracy_table[name]))
        log(f"  accuracy: " + "  ".join(
            f"{k}={v:.3f}" for k, v in accuracy_table[name].items()))
        # lower variants
        entries = []
        for variant in VARIANTS:
            hlo_path = art / f"{name}_{variant}_b{EVAL_BATCH}.hlo.txt"
            mpath = art / f"{name}_{variant}_b{EVAL_BATCH}.meta.json"
            if hlo_path.exists() and mpath.exists():
                entries.append(json.loads(mpath.read_text()))
                continue
            t0 = time.time()
            meta = lower_model(cfg, params, variant, ln_calib, EVAL_BATCH, hlo_path)
            meta["id"] = f"{name}_{variant}_b{EVAL_BATCH}"
            meta["model"] = name
            meta["weights"] = f"weights/{name}"
            meta["calib"] = f"calib/{name}"
            mpath.write_text(json.dumps(meta))
            entries.append(meta)
            log(f"  lowered {hlo_path.name} ({time.time()-t0:.1f}s, "
                f"{hlo_path.stat().st_size // 1024} KiB)")
        manifest["models"].extend(entries)
        return params, ln_calib

    cv_params = {}
    for name in CV_MODELS:
        cfg = MODEL_ZOO[name]
        cv_params[name] = build_model(name, cfg, cv_train, cv_eval,
                                      CV_STEPS, seed=sum(map(ord, name)) % 1000)

    for task in NLP_TASKS:
        cfg = bert_for_task(data.task_num_classes(task))
        tr, ev = nlp_data[task]
        build_model(f"bert_{task}", cfg, tr, ev, NLP_STEPS,
                    seed=1000 + sum(map(ord, task)) % 1000)

    # ---- serving artifacts (dynamic-batcher buckets) ---------------------
    if not args.skip_serving:
        log("[serving artifacts]")
        name = "deit_t"
        cfg = MODEL_ZOO[name]
        params, ln_calib = cv_params[name]
        for b in SERVING_BATCHES:
            hlo_path = art / f"{name}_fp32_sole_b{b}.hlo.txt"
            mpath = art / f"{name}_fp32_sole_b{b}.meta.json"
            if hlo_path.exists() and mpath.exists():
                manifest["models"].append(json.loads(mpath.read_text()))
                continue
            meta = lower_model(cfg, params, "fp32_sole", ln_calib, b, hlo_path)
            meta["id"] = f"{name}_fp32_sole_b{b}"
            meta["model"] = name
            meta["weights"] = f"weights/{name}"
            meta["calib"] = f"calib/{name}"
            mpath.write_text(json.dumps(meta))
            manifest["models"].append(meta)
            log(f"  lowered {hlo_path.name}")

    # ---- standalone op graphs -------------------------------------------
    log("[op kernels]")
    manifest["ops"] = lower_op_kernels(art, log)

    # ---- fig3 + golden + manifest ----------------------------------------
    (art / "fig3.json").write_text(json.dumps(fig3))
    log("[golden vectors]")
    gen_golden.generate_all(art / "golden", log=log)
    (art / "accuracy_py.json").write_text(json.dumps(accuracy_table))
    (art / "manifest.json").write_text(json.dumps(manifest, indent=1))
    log(f"artifacts complete in {time.time()-t_start:.0f}s -> {art}")


if __name__ == "__main__":
    main()
