"""Layer 2: transformer models in JAX with pluggable Softmax/LayerNorm.

The models (a ViT, a Swin-style windowed ViT surrogate, and a BERT-style
encoder) are written as pure functions over a params pytree so that:

* training (build-time, exact ops) uses ``jax.grad`` directly;
* the SOLE variants swap in the Layer-1 Pallas kernels
  (``kernels.e2softmax`` / ``kernels.ailayernorm``) **inside** the jitted
  forward, so AOT lowering produces a single HLO containing the kernels;
* prior-work approximations (Softermax, I-BERT) are available as ablation
  variants for the accuracy benches.

Ops selection is data-driven via :class:`OpsConfig` — this is the
"SOLE as a plugin" claim of the paper: the same trained weights run under
any (softmax x layernorm x matmul) combination without retraining.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import ailayernorm as ail_kernel
from .kernels import e2softmax as e2_kernel
from .kernels.ref import DEFAULT_E

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of one encoder model."""

    kind: str  # "vit" | "swin" | "bert"
    dim: int = 64
    depth: int = 4
    heads: int = 4
    mlp_ratio: int = 4
    n_classes: int = 10
    # vit/swin
    img_size: int = 32
    patch: int = 4
    window: int = 16  # swin: tokens per window
    # bert
    vocab: int = 64
    seq_len: int = 32

    @property
    def tokens(self) -> int:
        if self.kind == "bert":
            return self.seq_len
        return (self.img_size // self.patch) ** 2

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads


@dataclasses.dataclass(frozen=True)
class OpsConfig:
    """Which implementation each non-linear op uses (the SOLE plugin knob)."""

    softmax: str = "exact"  # exact | sole | softermax | ibert
    layernorm: str = "exact"  # exact | sole | ibert
    matmul: str = "fp32"  # fp32 | int8
    softmax_e: int = DEFAULT_E
    softmax_v: int = 32  # lane count for the pallas kernel
    # LayerNorm PTF calibration: name -> {"alpha": (C,), "zp": int, "s": float}
    ln_calib: dict | None = None
    use_pallas: bool = True  # False = pure-jnp twins (for training-side evals)

    def variant_name(self) -> str:
        mm = "int8" if self.matmul == "int8" else "fp32"
        if self.softmax == "sole" and self.layernorm == "sole":
            return f"{mm}_sole"
        if self.softmax == "exact" and self.layernorm == "exact":
            return mm
        return f"{mm}_{self.softmax}_{self.layernorm}"


EXACT = OpsConfig()


# ---------------------------------------------------------------------------
# Non-linear op implementations (jnp twins of kernels/ref.py)
# ---------------------------------------------------------------------------

def _pow2i(x: jnp.ndarray) -> jnp.ndarray:
    """Exact 2^x for integer-valued x (ldexp; XLA exp2 is off at integers)."""
    return jnp.ldexp(jnp.float32(1.0), x.astype(jnp.int32))


def e2softmax_jnp(x: jnp.ndarray, e: int = DEFAULT_E) -> jnp.ndarray:
    """Two-pass jnp E2Softmax (vectorized twin of ref.e2softmax_twopass_f)."""
    xmax = jnp.max(x, axis=-1, keepdims=True)
    d = jnp.clip(jnp.round((x - xmax) * float(1 << e)), -255.0, 0.0)
    f = 8
    v = d * float(1 << f)
    t = v + jnp.floor(v * 0.5) - jnp.floor(v * 0.0625)
    k = jnp.floor((-t + float(1 << (f + e - 1))) / float(1 << (f + e)))
    k = jnp.clip(k, 0.0, 15.0)
    p = _pow2i(-k)
    ssum = jnp.sum(p, axis=-1, keepdims=True)
    k_s = jnp.floor(jnp.log2(ssum))
    k_s = jnp.where(_pow2i(k_s) > ssum, k_s - 1.0, k_s)
    k_s = jnp.where(_pow2i(k_s + 1.0) <= ssum, k_s + 1.0, k_s)
    frac = ssum * _pow2i(-k_s) - 1.0
    c = jnp.where(frac >= 0.5, 1.136, 1.636)
    return c * _pow2i(-(k + k_s + 1.0))


def softermax_jnp(x: jnp.ndarray, frac_bits: int = 8) -> jnp.ndarray:
    """Softermax: base-2 softmax with 2^-frac_bits quantized intermediates."""
    scale = float(1 << frac_bits)
    z = jnp.floor(x / math.log(2.0) * scale) / scale
    z = z - jnp.ceil(jnp.max(z, axis=-1, keepdims=True))
    p = jnp.exp2(z)
    q = jnp.floor(p * scale) / scale
    s = jnp.sum(q, axis=-1, keepdims=True)
    return q / jnp.where(s > 0, s, 1.0)


def ibert_softmax_jnp(x: jnp.ndarray, scale: float = 1.0 / 16) -> jnp.ndarray:
    """I-BERT i-exp softmax (integer-polynomial exp), jnp twin of ref."""
    q = jnp.floor(x / scale)
    q = q - jnp.max(q, axis=-1, keepdims=True)
    ln2_q = math.floor(math.log(2.0) / scale)
    z = jnp.floor(-q / ln2_q)
    p = q + z * ln2_q
    qb = math.floor(1.353 / scale)
    qc = math.floor(0.344 / (0.3585 * scale * scale))
    qout = (p + qb) ** 2 + qc
    qexp = jnp.floor(qout * _pow2i(-z))
    s = jnp.sum(qexp, axis=-1, keepdims=True)
    return qexp / jnp.where(s > 0, s, 1.0)


def layernorm_exact_jnp(x, gamma, beta, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return gamma * (x - mu) / jnp.sqrt(var + eps) + beta


def ibert_layernorm_jnp(x, gamma, beta, scale: float = 1.0 / 64):
    q = jnp.floor(x / scale)
    mu = jnp.floor(jnp.mean(q, axis=-1, keepdims=True))
    dv = q - mu
    var = jnp.floor(jnp.mean(dv * dv, axis=-1, keepdims=True))
    std = jnp.floor(jnp.sqrt(var)) + 1.0
    return gamma * dv / std + beta


def ailayernorm_jnp(x, gamma, beta, alpha, s, zp):
    """Pure-jnp AILayerNorm twin (used when use_pallas=False)."""
    pot = _pow2i(alpha)
    scale = s * pot
    codes = jnp.clip(jnp.round(x / scale) + zp, 0, 255)
    xi = codes - float(zp)
    d = xi * pot
    mag = jnp.minimum(jnp.abs(xi), 255.0)
    sflag = (mag >= 64.0).astype(x.dtype)
    half = _pow2i(1.0 + 2.0 * sflag)
    y4 = jnp.minimum(jnp.floor((mag + half) * _pow2i(-(2.0 + 2.0 * sflag))), 15.0)
    sq = (y4 * y4) * _pow2i(4.0 * sflag) * pot * pot
    cdim = x.shape[-1]
    ex = jnp.mean(d, axis=-1, keepdims=True)
    ex2 = jnp.sum(sq, axis=-1, keepdims=True) * 16.0 / cdim
    var = jnp.maximum(ex2 - ex * ex, 1e-12)
    return gamma * (d - ex) / jnp.sqrt(var) + beta


# ---------------------------------------------------------------------------
# Op dispatch
# ---------------------------------------------------------------------------

def apply_softmax(logits: jnp.ndarray, ops: OpsConfig) -> jnp.ndarray:
    if ops.softmax == "exact":
        return jax.nn.softmax(logits, axis=-1)
    if ops.softmax == "sole":
        if ops.use_pallas:
            # block_rows=128: fewer, wider grid steps — 13x faster on the
            # CPU PJRT backend at identical (bit-exact) results; still a
            # VMEM-friendly tile architecturally (EXPERIMENTS.md §Perf)
            probs, _ = e2_kernel.e2softmax(logits, e=ops.softmax_e, v=ops.softmax_v,
                                           block_rows=128)
            return probs
        return e2softmax_jnp(logits, e=ops.softmax_e)
    if ops.softmax == "softermax":
        return softermax_jnp(logits)
    if ops.softmax == "ibert":
        return ibert_softmax_jnp(logits)
    raise ValueError(f"unknown softmax {ops.softmax}")


def apply_layernorm(x: jnp.ndarray, gamma, beta, name: str, ops: OpsConfig,
                    capture: dict | None = None) -> jnp.ndarray:
    if capture is not None:
        capture.setdefault("ln_inputs", {})[name] = x
    if ops.layernorm == "exact":
        return layernorm_exact_jnp(x, gamma, beta)
    if ops.layernorm == "ibert":
        return ibert_layernorm_jnp(x, gamma, beta)
    if ops.layernorm == "sole":
        calib = (ops.ln_calib or {}).get(name)
        if calib is None:
            raise ValueError(f"SOLE layernorm needs PTF calibration for {name}")
        alpha = jnp.asarray(calib["alpha"], dtype=jnp.float32)
        if ops.use_pallas:
            pot = _pow2i(alpha)
            codes = jnp.clip(jnp.round(x / (calib["s"] * pot)) + calib["zp"], 0, 255)
            return ail_kernel.ailayernorm(codes, alpha, gamma, beta,
                                          zp=int(calib["zp"]), block_rows=64)
        return ailayernorm_jnp(x, gamma, beta, alpha, calib["s"], int(calib["zp"]))
    raise ValueError(f"unknown layernorm {ops.layernorm}")


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None, ops: OpsConfig) -> jnp.ndarray:
    """Matmul with optional INT8 fake-quant (per-channel weights, dynamic
    per-tensor activations) — the paper's INT8 baseline setting."""
    if ops.matmul == "int8":
        aw = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0 + 1e-12
        wq = jnp.round(w / aw) * aw
        ax = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
        xq = jnp.round(x / ax) * ax
        y = xq @ wq
    else:
        y = x @ w
    return y if b is None else y + b


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_block(key, dim: int, mlp: int) -> Params:
    k = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(dim)
    return {
        "ln1_g": jnp.ones(dim), "ln1_b": jnp.zeros(dim),
        "wqkv": jax.random.normal(k[0], (dim, 3 * dim)) * s,
        "bqkv": jnp.zeros(3 * dim),
        "wo": jax.random.normal(k[1], (dim, dim)) * s,
        "bo": jnp.zeros(dim),
        "ln2_g": jnp.ones(dim), "ln2_b": jnp.zeros(dim),
        "w1": jax.random.normal(k[2], (dim, mlp)) * s,
        "b1": jnp.zeros(mlp),
        "w2": jax.random.normal(k[3], (mlp, dim)) * (1.0 / math.sqrt(mlp)),
        "b2": jnp.zeros(dim),
    }


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, cfg.depth + 3)
    p: Params = {"blocks": [_init_block(keys[i], cfg.dim, cfg.dim * cfg.mlp_ratio)
                            for i in range(cfg.depth)]}
    if cfg.kind == "bert":
        p["tok_emb"] = jax.random.normal(keys[-1], (cfg.vocab, cfg.dim)) * 0.02
        p["pos_emb"] = jax.random.normal(keys[-2], (cfg.tokens, cfg.dim)) * 0.02
    else:
        patch_dim = cfg.patch * cfg.patch
        p["patch_w"] = jax.random.normal(keys[-1], (patch_dim, cfg.dim)) / math.sqrt(patch_dim)
        p["patch_b"] = jnp.zeros(cfg.dim)
        p["pos_emb"] = jax.random.normal(keys[-2], (cfg.tokens, cfg.dim)) * 0.02
    p["lnf_g"] = jnp.ones(cfg.dim)
    p["lnf_b"] = jnp.zeros(cfg.dim)
    p["head_w"] = jax.random.normal(keys[-3], (cfg.dim, cfg.n_classes)) / math.sqrt(cfg.dim)
    p["head_b"] = jnp.zeros(cfg.n_classes)
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _attention(x: jnp.ndarray, blk: Params, cfg: ModelConfig, ops: OpsConfig,
               window: int | None) -> jnp.ndarray:
    """(B, T, D) multi-head self-attention, optionally windowed (swin)."""
    b, t, d = x.shape
    h, hd = cfg.heads, cfg.head_dim
    qkv = dense(x, blk["wqkv"], blk["bqkv"], ops)  # (B, T, 3D)
    qkv = qkv.reshape(b, t, 3, h, hd)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # (B, T, H, hd)
    if window is not None:
        w = window
        nw = t // w
        q = q.reshape(b, nw, w, h, hd)
        k = k.reshape(b, nw, w, h, hd)
        v = v.reshape(b, nw, w, h, hd)
        logits = jnp.einsum("bnwhd,bnvhd->bnhwv", q, k) / math.sqrt(hd)
        probs = apply_softmax(logits, ops)
        out = jnp.einsum("bnhwv,bnvhd->bnwhd", probs, v).reshape(b, t, h, hd)
    else:
        logits = jnp.einsum("bthd,bshd->bhts", q, k) / math.sqrt(hd)
        probs = apply_softmax(logits, ops)
        out = jnp.einsum("bhts,bshd->bthd", probs, v)
    out = out.reshape(b, t, d)
    return dense(out, blk["wo"], blk["bo"], ops)


def forward(params: Params, x: jnp.ndarray, cfg: ModelConfig,
            ops: OpsConfig = EXACT, capture: dict | None = None) -> jnp.ndarray:
    """Model forward -> (B, n_classes) logits.

    ``x``: images (B, H, W, 1) f32 for vit/swin, or token ids (B, T) i32
    for bert.  ``capture`` (eager-mode only) collects LN inputs for PTF
    calibration.
    """
    if cfg.kind == "bert":
        tokens = params["tok_emb"][x] + params["pos_emb"]
    else:
        b = x.shape[0]
        n = cfg.img_size // cfg.patch
        xp = x.reshape(b, n, cfg.patch, n, cfg.patch)
        xp = xp.transpose(0, 1, 3, 2, 4).reshape(b, n * n, cfg.patch * cfg.patch)
        tokens = dense(xp, params["patch_w"], params["patch_b"], ops) + params["pos_emb"]

    h = tokens
    for i, blk in enumerate(params["blocks"]):
        window = cfg.window if cfg.kind == "swin" else None
        ln1 = apply_layernorm(h, blk["ln1_g"], blk["ln1_b"], f"b{i}.ln1", ops, capture)
        if cfg.kind == "swin" and i % 2 == 1:
            # shifted windows couple neighbouring windows between blocks
            shift = cfg.window // 4
            ln1s = jnp.roll(ln1, shift, axis=1)
            att = _attention(ln1s, blk, cfg, ops, window)
            att = jnp.roll(att, -shift, axis=1)
        else:
            att = _attention(ln1, blk, cfg, ops, window)
        h = h + att
        ln2 = apply_layernorm(h, blk["ln2_g"], blk["ln2_b"], f"b{i}.ln2", ops, capture)
        mlp = dense(jax.nn.gelu(dense(ln2, blk["w1"], blk["b1"], ops)), blk["w2"], blk["b2"], ops)
        h = h + mlp

    h = apply_layernorm(h, params["lnf_g"], params["lnf_b"], "lnf", ops, capture)
    pooled = jnp.mean(h, axis=1)
    return dense(pooled, params["head_w"], params["head_b"], ops)


def capture_attn_logits(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> list:
    """Eager helper: exact forward that also returns every block's raw
    attention logits (pre-softmax) for Fig 3 and softmax-scale studies."""
    logits_all: list = []
    ops = EXACT
    if cfg.kind == "bert":
        tokens = params["tok_emb"][x] + params["pos_emb"]
    else:
        b = x.shape[0]
        n = cfg.img_size // cfg.patch
        xp = x.reshape(b, n, cfg.patch, n, cfg.patch)
        xp = xp.transpose(0, 1, 3, 2, 4).reshape(b, n * n, cfg.patch * cfg.patch)
        tokens = dense(xp, params["patch_w"], params["patch_b"], ops) + params["pos_emb"]
    h = tokens
    for blk in params["blocks"]:
        window = cfg.window if cfg.kind == "swin" else None
        ln1 = layernorm_exact_jnp(h, blk["ln1_g"], blk["ln1_b"])
        bdim, t, d = ln1.shape
        hh, hd = cfg.heads, cfg.head_dim
        qkv = (ln1 @ blk["wqkv"] + blk["bqkv"]).reshape(bdim, t, 3, hh, hd)
        q, k = qkv[:, :, 0], qkv[:, :, 1]
        if window is not None:
            nw = t // window
            qw = q.reshape(bdim, nw, window, hh, hd)
            kw = k.reshape(bdim, nw, window, hh, hd)
            lg = jnp.einsum("bnwhd,bnvhd->bnhwv", qw, kw) / math.sqrt(hd)
        else:
            lg = jnp.einsum("bthd,bshd->bhts", q, k) / math.sqrt(hd)
        logits_all.append(lg)
        att = _attention(ln1, blk, cfg, ops, window)
        h = h + att
        ln2 = layernorm_exact_jnp(h, blk["ln2_g"], blk["ln2_b"])
        h = h + (jax.nn.gelu(ln2 @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"])
    return logits_all


# ---------------------------------------------------------------------------
# Model zoo (the paper's model list mapped to build-time-trainable surrogates)
# ---------------------------------------------------------------------------

MODEL_ZOO: dict[str, ModelConfig] = {
    # Table I surrogates (CV)
    "deit_t": ModelConfig(kind="vit", dim=64, depth=4, heads=4),
    "deit_s": ModelConfig(kind="vit", dim=96, depth=6, heads=6),
    "swin_t": ModelConfig(kind="swin", dim=64, depth=4, heads=4, window=16),
    # Table II surrogate (NLP) — instantiated once per task
    "bert": ModelConfig(kind="bert", dim=64, depth=4, heads=4, n_classes=2),
}


def bert_for_task(n_classes: int) -> ModelConfig:
    return dataclasses.replace(MODEL_ZOO["bert"], n_classes=n_classes)
