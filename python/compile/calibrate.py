"""Post-training calibration: PTF for AILayerNorm + Fig 3 statistics.

PTF (Power-of-Two Factor, FQ-ViT) assigns each LayerNorm input channel a
power-of-two factor alpha so one layer-wise 8-bit scale covers channels with
very different ranges — the inter-channel variation that plain per-tensor
quantization destroys.  This runs once per trained model on a calibration
batch with exact ops, capturing every LN input.

Also dumps the paper's Fig 3 ingredient: the distribution of
exp(X_i - X_max) in the log2 domain for real attention logits.
"""

from __future__ import annotations

import json
import math
from pathlib import Path

import numpy as np

from .model import EXACT, ModelConfig, Params, capture_attn_logits, forward

ALPHA_MAX = 5  # PTF factor range [0, 2^5] (paper/FQ-ViT use small alpha)


def ptf_calibrate(
    params: Params,
    x_calib: np.ndarray,
    cfg: ModelConfig,
    *,
    alpha_max: int = ALPHA_MAX,
) -> dict[str, dict]:
    """Run a capture forward and fit per-LN {alpha (C,), s, zp}.

    alpha_c = round(log2(range_c / range_base)) with the base at the 10th
    percentile channel; s covers the largest post-shift channel range with
    codes in [zp-127, zp+127] (zp = 128, symmetric u8).
    """
    capture: dict = {}
    forward(params, x_calib, cfg, EXACT, capture=capture)
    out: dict[str, dict] = {}
    for name, xin in capture["ln_inputs"].items():
        arr = np.asarray(xin, dtype=np.float64).reshape(-1, xin.shape[-1])
        r_c = np.abs(arr).max(axis=0) + 1e-12
        base = max(np.quantile(r_c, 0.10), 1e-9)
        alpha = np.clip(np.round(np.log2(r_c / base)), 0, alpha_max).astype(np.int32)
        s = float((r_c / np.power(2.0, alpha)).max() / 127.0)
        out[name] = {"alpha": alpha.tolist(), "s": s, "zp": 128}
    return out


def softmax_input_stats(params: Params, x_calib: np.ndarray, cfg: ModelConfig) -> dict:
    """Fig 3: histogram of log2(exp(x - xmax)) = (x - xmax)/ln2 over all
    attention logits, plus the moments the paper's 'close to normal on a
    log2 scale' claim rests on."""
    logit_blocks = capture_attn_logits(params, x_calib, cfg)
    vals = []
    for lg in logit_blocks:
        a = np.asarray(lg, dtype=np.float64)
        z = a - a.max(axis=-1, keepdims=True)
        vals.append((z / math.log(2.0)).ravel())
    allv = np.concatenate(vals)
    # clip the -inf-ish tail for the histogram (paper plots a finite range)
    clipped = np.clip(allv, -24.0, 0.0)
    hist, edges = np.histogram(clipped, bins=48, range=(-24.0, 0.0))
    return {
        "hist": hist.tolist(),
        "edges": edges.tolist(),
        "mean": float(allv.mean()),
        "std": float(allv.std()),
        "frac_below_kmax": float((allv < -15.0).mean()),
        "count": int(allv.size),
    }


def save_calib(path: Path, calib: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(calib))


def load_calib(path: Path) -> dict:
    calib = json.loads(path.read_text())
    return calib
