"""Deterministic synthetic datasets (build-time only).

The paper evaluates on ImageNet-1K (DeiT/Swin) and GLUE/SQuAD (BERT-Base).
Neither is available offline, so we substitute procedurally generated
datasets that are genuinely *learnable* — the models are really trained and
the FP32 -> +SOLE accuracy delta (the paper's claim) is measured on real
decision boundaries, not noise.  See DESIGN.md §2 for why this preserves
the relevant behaviour.

Everything is seeded and pure-numpy; the Rust side reads the exported
eval splits through ``tensor/`` (same little-endian raw + JSON manifest).
"""

from __future__ import annotations

import numpy as np

IMG_SIZE = 32
N_CLASSES = 10
VOCAB = 64
SEQ_LEN = 32

# The eight GLUE/SQuAD analogue tasks (Table II columns).  Each one is a
# different rule over token sequences; all are binary except "mnli" (3-way),
# mirroring the benchmark's mix.
NLP_TASKS = ["cola", "mrpc", "sst2", "qqp", "mnli", "qnli", "rte", "squad"]


# ---------------------------------------------------------------------------
# CV: 10-class procedural shapes over 32x32 grayscale
# ---------------------------------------------------------------------------

def _render_class(cls: int, rng: np.random.Generator) -> np.ndarray:
    """Render one 32x32 image of class ``cls`` with per-sample jitter."""
    n = IMG_SIZE
    yy, xx = np.mgrid[0:n, 0:n].astype(np.float64)
    phase = rng.uniform(0, 2 * np.pi)
    freq = rng.uniform(0.5, 1.0)
    cx, cy = rng.uniform(10, 22, size=2)
    r = rng.uniform(6, 12)
    if cls == 0:  # horizontal stripes
        img = np.sin(yy * freq + phase)
    elif cls == 1:  # vertical stripes
        img = np.sin(xx * freq + phase)
    elif cls == 2:  # diagonal stripes
        img = np.sin((xx + yy) * freq * 0.7 + phase)
    elif cls == 3:  # filled circle
        img = ((xx - cx) ** 2 + (yy - cy) ** 2 < r * r).astype(np.float64)
    elif cls == 4:  # square ring
        d = np.maximum(np.abs(xx - cx), np.abs(yy - cy))
        img = ((d > r * 0.5) & (d < r)).astype(np.float64)
    elif cls == 5:  # checkerboard
        k = int(rng.integers(3, 6))
        img = (((xx // k) + (yy // k)) % 2).astype(np.float64)
    elif cls == 6:  # radial gradient
        img = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2) / n
    elif cls == 7:  # plus / cross
        w = rng.uniform(1.5, 3.5)
        img = ((np.abs(xx - cx) < w) | (np.abs(yy - cy) < w)).astype(np.float64)
    elif cls == 8:  # dot lattice
        k = int(rng.integers(5, 8))
        img = (((xx % k) < 2) & ((yy % k) < 2)).astype(np.float64)
    else:  # 9: half-plane with random orientation
        th = rng.uniform(0, 2 * np.pi)
        img = ((xx - n / 2) * np.cos(th) + (yy - n / 2) * np.sin(th) > 0).astype(np.float64)
    img = img - img.mean()
    scale = img.std() + 1e-6
    img = img / scale + rng.normal(0, 0.35, size=img.shape)
    return img.astype(np.float32)


def shapes_dataset(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """n images -> (x: (n, 32, 32, 1) f32, y: (n,) i32), balanced classes."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, N_CLASSES, size=n).astype(np.int32)
    x = np.stack([_render_class(int(c), rng) for c in labels])
    return x[..., None], labels


# ---------------------------------------------------------------------------
# NLP: rule-labeled token sequences (GLUE/SQuAD analogues)
# ---------------------------------------------------------------------------

def _label_rule(task: str, seq: np.ndarray, rng: np.random.Generator) -> int:
    """Deterministic labeling rule per task (the 'grammar' to learn)."""
    if task == "cola":  # acceptability: majority of adjacent pairs ordered
        asc = int(np.sum(seq[1:] >= seq[:-1]))
        return int(asc > (len(seq) - 1) // 2)
    if task == "mrpc":  # paraphrase: halves have close histograms
        a, b = seq[: len(seq) // 2], seq[len(seq) // 2:]
        return int(abs(int(a.sum()) - int(b.sum())) < VOCAB)
    if task == "sst2":  # sentiment: positive tokens (upper half of vocab) majority
        return int((seq >= VOCAB // 2).sum() > len(seq) // 2)
    if task == "qqp":  # duplicate: first and last quarter share a token
        a, b = set(seq[: len(seq) // 4].tolist()), set(seq[-len(seq) // 4:].tolist())
        return int(len(a & b) >= 1)
    if task == "mnli":  # 3-way: compare sum of halves
        a, b = int(seq[: len(seq) // 2].sum()), int(seq[len(seq) // 2:].sum())
        d = a - b
        return 0 if d > VOCAB // 2 else (1 if d < -VOCAB // 2 else 2)
    if task == "qnli":  # answerability: token 0's value appears again later
        return int(seq[0] in seq[1:])
    if task == "rte":  # entailment: max token in first half >= max in second
        return int(seq[: len(seq) // 2].max() >= seq[len(seq) // 2:].max())
    if task == "squad":  # span: position parity of the vocab-max token
        return int(int(np.argmax(seq)) % 2)
    raise ValueError(f"unknown task {task}")


def tokens_dataset(task: str, n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """n sequences -> (x: (n, SEQ_LEN) i32, y: (n,) i32)."""
    rng = np.random.default_rng(seed + sum(map(ord, task)))
    xs = rng.integers(0, VOCAB, size=(n, SEQ_LEN)).astype(np.int32)
    ys = np.array([_label_rule(task, s, rng) for s in xs], dtype=np.int32)
    return xs, ys


def task_num_classes(task: str) -> int:
    return 3 if task == "mnli" else 2
