"""E2Softmax as a Pallas kernel (Layer 1).

Implements Algorithm 1 in its V-lane chunked-online form — the dataflow of
the paper's E2Softmax Unit (Fig. 4): each grid step owns a block of rows;
inside the kernel a ``fori_loop`` streams V-column slices through the
Max / Log2Exp / Reduction stages carrying the running (max, sum) exactly
like the unit's GlobalMax register and Sum Buffer, then stage 2 applies the
correction and the Approximate Log-based Divider.

TPU adaptation (DESIGN.md §3): the 4-bit Log2Exp codes for a whole
(block_rows x L) slab live in VMEM — this is the paper's shrunken ping-pong
Output Buffer; all arithmetic is shift/round/select (VPU work, exact in
f32), there is deliberately no MXU involvement.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO so the Rust runtime can
run the same computation (see /opt/xla-example/README.md).

Bit-exactness: every intermediate is an integer-valued f32 within the
mantissa-exact range provided sum_q15 < 2^24, i.e. rows of length
L <= 2^9 = 512 are bit-identical to ``ref.e2softmax_online_int(chunk=V)``;
longer rows agree to ~2^-24 relative on the sum path (tested both ways).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pow2i(x: jnp.ndarray) -> jnp.ndarray:
    """Exact 2^x for integer-valued f32 x (XLA's exp2 is transcendental and
    off by ULPs at integer arguments — ldexp is exact)."""
    return jnp.ldexp(jnp.float32(1.0), x.astype(jnp.int32))

# Contract constants (shared with ref.py / rust).
_F = ref.LOG2EXP_F
_KMAX = float(ref.K_MAX)
_SUM_FRAC = ref.SUM_FRAC
_C0 = float(ref.ALDIV_C0)
_C1 = float(ref.ALDIV_C1)
_ALDIV_Q = ref.ALDIV_Q
_OUT_FRAC = ref.OUT_FRAC


def _log2exp(d: jnp.ndarray, e: int) -> jnp.ndarray:
    """Vectorized Log2Exp on integer-valued (<= 0) f32 code deltas.

    Matches ref.log2exp_int: t = v + v>>1 - v>>4 with floor shifts on the
    Q(F) value, round-half-up, clip to [0, 15].
    """
    v = d * float(1 << _F)
    t = v + jnp.floor(v * 0.5) - jnp.floor(v * 0.0625)
    k = jnp.floor((-t + float(1 << (_F + e - 1))) * (1.0 / float(1 << (_F + e))))
    return jnp.clip(k, 0.0, _KMAX)


def _floor_log2(x: jnp.ndarray) -> jnp.ndarray:
    """Exact floor(log2(x)) for integer-valued f32 x >= 1.

    jnp.log2 alone can round 2^n - eps up to n; correct with one
    compare-and-fix step in each direction.
    """
    k = jnp.floor(jnp.log2(x))
    k = jnp.where(_pow2i(k) > x, k - 1.0, k)
    k = jnp.where(_pow2i(k + 1.0) <= x, k + 1.0, k)
    return k


def _e2softmax_kernel(x_ref, out_ref, codes_ref, *, e: int, v: int, length: int):
    """One block of rows: chunked-online stage 1 + divider stage 2."""
    x = x_ref[...]  # (R, L) f32 logits
    rows = x.shape[0]
    n_chunks = length // v

    # --- quantize to integer codes relative to the row max --------------
    # d_full = clip(round((x - rowmax) * 2^e), -255, 0); integer-valued f32.
    # The *online* pass below re-references each slice to the running max,
    # so we keep raw codes q = round(x * 2^e) clipped to a wide window
    # around the row max (wide enough that the Log2Exp saturation at k=15
    # makes the exact window irrelevant).
    rowmax = jnp.max(x, axis=-1, keepdims=True)
    q = jnp.round((x - rowmax) * float(1 << e))
    q = jnp.clip(q, -255.0, 0.0)  # codes relative to global row max

    def body(c, carry):
        m, s, ks = carry
        sl = jax.lax.dynamic_slice(q, (0, c * v), (rows, v))  # (R, V)
        local = jnp.max(sl, axis=-1, keepdims=True)
        m_new = jnp.maximum(local, m)
        sub = _log2exp(m - m_new, e)
        s = jnp.floor(s * _pow2i(-sub))  # sum >> sub (floor shift)
        k_sl = _log2exp(sl - m_new, e)  # (R, V)
        s = s + jnp.sum(_pow2i(_SUM_FRAC - k_sl), axis=-1, keepdims=True)
        # store k and the slice's running max for the stage-2 correction
        ks = jax.lax.dynamic_update_slice(ks, k_sl + (-m_new) * 1024.0, (0, c * v))
        return m_new, s, ks

    # carry: running max m (R,1), sum_q15 s (R,1), packed (k + (-m)*1024)
    m0 = jnp.full((rows, 1), -1024.0, dtype=jnp.float32)
    s0 = jnp.zeros((rows, 1), dtype=jnp.float32)
    ks0 = jnp.zeros((rows, length), dtype=jnp.float32)
    # first chunk initializes the max without a shift (m0 is a -inf proxy:
    # codes are >= -255 so -1024 never wins and Log2Exp(m0-m1) saturates,
    # flooring an all-zero sum — harmless and identical to ref's None case)
    m, s, ks = jax.lax.fori_loop(0, n_chunks, body, (m0, s0, ks0))

    # unpack: k_i and the per-element chunk max m_c(i)
    mneg = jnp.floor(ks * (1.0 / 1024.0))  # (-m_c) packed in high bits
    k = ks - mneg * 1024.0
    m_c = -mneg

    # --- stage 2: correction + ALDivision -------------------------------
    sub2 = _log2exp(m_c - m, e)
    k_y = k + sub2
    msb = _floor_log2(s)  # s >= 2^15 always (global max contributes 2^15)
    k_s = msb - float(_SUM_FRAC)
    # bit below the leading one: floor(s / 2^(msb-1)) - 2 in {0, 1}
    s1 = jnp.floor(s * _pow2i(-(msb - 1.0))) - 2.0
    c = jnp.where(s1 > 0.5, _C1, _C0)
    shift = k_y + k_s + 1.0
    out_q = jnp.floor(c * _pow2i(-shift))  # Q23 integer-valued
    out_ref[...] = out_q * (1.0 / float(1 << _ALDIV_Q))
    # round-half-up 8-bit output code (scale 2^-8)
    half = float(1 << (_ALDIV_Q - _OUT_FRAC - 1))
    code = jnp.floor((out_q + half) * (1.0 / float(1 << (_ALDIV_Q - _OUT_FRAC))))
    codes_ref[...] = jnp.minimum(code, 255.0)


@functools.partial(jax.jit, static_argnames=("e", "v", "block_rows", "interpret"))
def e2softmax(
    x: jnp.ndarray,
    *,
    e: int = ref.DEFAULT_E,
    v: int = 32,
    block_rows: int = 8,
    interpret: bool = True,
):
    """Chunked-online E2Softmax over the last axis of ``x``.

    Args:
      x: (..., L) f32 logits; L must be a multiple of ``v``.
      e: power-of-two input scale exponent (input scale 2^-e).
      v: lane count of the simulated unit (paper: 32).
      block_rows: rows per Pallas grid step (VMEM tile height).

    Returns:
      (probs, codes): f32 probabilities (Q23-grid values) and the 8-bit
      output codes (as f32 integers, scale 2^-8).
    """
    orig_shape = x.shape
    length = orig_shape[-1]
    if length % v != 0:
        raise ValueError(f"L={length} must be a multiple of v={v}")
    rows = 1
    for dim in orig_shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, length).astype(jnp.float32)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, length), jnp.float32)], axis=0)
    grid = (x2.shape[0] // block_rows,)
    kern = functools.partial(_e2softmax_kernel, e=e, v=v, length=length)
    probs, codes = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, length), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, length), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, length), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x2.shape[0], length), jnp.float32),
            jax.ShapeDtypeStruct((x2.shape[0], length), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    if pad:
        probs = probs[:rows]
        codes = codes[:rows]
    return probs.reshape(orig_shape), codes.reshape(orig_shape)


def vmem_bytes(block_rows: int, length: int) -> dict:
    """Static VMEM footprint model of one grid step (DESIGN.md §7 L1).

    On a real TPU the k-codes are 4-bit (int8-packed here); interpret mode
    materializes f32, so this reports the *architectural* footprint the
    paper's buffers imply alongside the interpret-mode one.
    """
    r, l = block_rows, length
    return {
        "input_f32": 4 * r * l,
        "arch_codes_4bit": (r * l) // 2,          # the paper's Output Buffer
        "arch_sum_q15_32bit": 4 * r,              # Sum Buffer
        "arch_max_16bit": 2 * r,                  # GlobalMax registers
        "interpret_codes_f32": 4 * r * l,
        "total_arch": 4 * r * l + (r * l) // 2 + 6 * r,
    }
