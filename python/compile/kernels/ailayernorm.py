"""AILayerNorm as a Pallas kernel (Layer 1).

Implements Algorithm 2: the two-stage AILayerNorm Unit dataflow (Fig. 5).
Stage 1 (statistic calculation) consumes PTF-quantized u8 codes, applies
dynamic 8->4-bit compression, squares through the 16-entry LUT (expressed
as y*y — identical values, the LUT is a hardware implementation choice),
decompresses with the << 4s shift, PTF-shifts by << 2*alpha, and reduces.
Stage 2 (affine transform) computes A = gamma * std_inv and
Y = A * (D - mu) + B.

TPU adaptation (DESIGN.md §3): a (block_rows x C) slab of 8-bit codes plus
the per-channel alpha/gamma/beta vectors live in VMEM (the unit's Input
Buffer + parameter registers); statistics are row reductions on the VPU.
The x^-0.5 is evaluated with the same 64-entry Q16 LUT as the hardware
(gathered from a constant table), not with a float rsqrt.

Bit-exactness: stage-1 sums are exact while E_x2 < 2^24 (e.g. C <= 256 with
alpha <= 2); beyond that f32 accumulation agrees with the integer reference
to ~2^-24 relative, far below the 4-bit compression error (paper: ~0.2% on
E(x^2)).  Tests cover both regimes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pow2i(x: jnp.ndarray) -> jnp.ndarray:
    """Exact 2^x for integer-valued f32 x (XLA's exp2 is transcendental and
    off by ULPs at integer arguments — ldexp is exact)."""
    return jnp.ldexp(jnp.float32(1.0), x.astype(jnp.int32))

_LUT_BITS = ref.RSQRT_LUT_BITS
_LUT_Q = ref.RSQRT_LUT_Q
_RSQRT_TABLE = jnp.array(ref.rsqrt_lut(), dtype=jnp.float32)


def _floor_log4(x: jnp.ndarray) -> jnp.ndarray:
    """Exact floor(log4(x)) (= k with 4^k <= x < 4^(k+1)) for f32 x > 0."""
    k = jnp.floor(jnp.log2(x) * 0.5)
    k = jnp.where(_pow2i(2.0 * k) > x, k - 1.0, k)
    k = jnp.where(_pow2i(2.0 * (k + 1.0)) <= x, k + 1.0, k)
    return k


def rsqrt_lut_f(var: jnp.ndarray, table: jnp.ndarray | None = None) -> jnp.ndarray:
    """The hardware x^-0.5: normalize to 4^k * v, v in [1,4); 64-entry LUT.

    Matches ref.rsqrt_hw on every input where f32 normalization is exact.
    ``table`` is threaded in as a kernel operand (pallas kernels cannot
    capture array constants); defaults to the module-level table outside
    pallas.
    """
    if table is None:
        table = _RSQRT_TABLE
    k = _floor_log4(var)
    v = var * _pow2i(-2.0 * k)
    idx = jnp.floor((v - 1.0) * float(1 << _LUT_BITS) * (1.0 / 3.0))
    idx = jnp.clip(idx, 0.0, float((1 << _LUT_BITS) - 1))
    # gather-free lookup: the stablehlo->HLO-text conversion produces a
    # gather that xla_extension 0.5.1 executes as zeros, so select the
    # entry with a one-hot reduction instead (64 compares per row, cheap —
    # and closer to how the hardware's ROM decoder actually works).
    flat = table.reshape(1, 1 << _LUT_BITS)
    iota = jax.lax.broadcasted_iota(jnp.float32, (1, 1 << _LUT_BITS), 1)
    idx2 = idx.reshape(-1, 1)
    onehot = (iota == idx2).astype(jnp.float32)  # (N, 64)
    val = jnp.sum(onehot * flat, axis=-1).reshape(var.shape)
    return val * (1.0 / float(1 << _LUT_Q)) * _pow2i(-k)


def _ailayernorm_kernel(x_ref, alpha_ref, gamma_ref, beta_ref, lut_ref, out_ref, *, zp: int, cdim: int):
    """One block of rows through both AILayerNorm stages."""
    codes = x_ref[...]  # (R, C) u8 codes as f32 integers
    alpha = alpha_ref[...]  # (1, C)
    gamma = gamma_ref[...]
    beta = beta_ref[...]
    lut = lut_ref[...]

    # ---- Stage 1: statistic calculation --------------------------------
    xi = codes - float(zp)  # signed 9-bit
    pot = _pow2i(alpha)
    d = xi * pot  # D_i = (X_i - zp) << alpha_i

    mag = jnp.minimum(jnp.abs(xi), 255.0)
    sflag = (mag >= 64.0).astype(jnp.float32)
    # DynamicCompress: round-to-nearest bit-select y ~ x >> (2 + 2s)
    half = _pow2i(1.0 + 2.0 * sflag)  # 2 or 8 = half LSB
    y4 = jnp.minimum(jnp.floor((mag + half) * _pow2i(-(2.0 + 2.0 * sflag))), 15.0)
    # Square LUT + Decompress (<< 4s) + PTF shift (<< 2*alpha)
    sq = (y4 * y4) * _pow2i(4.0 * sflag) * pot * pot

    ex = jnp.sum(d, axis=-1, keepdims=True)
    ex2 = jnp.sum(sq, axis=-1, keepdims=True) * 16.0  # deferred << 4

    inv_c = 1.0 / float(cdim)
    mean = ex * inv_c
    var = ex2 * inv_c - mean * mean
    std_inv = jnp.where(var > 0.0, rsqrt_lut_f(jnp.maximum(var, 1e-30), lut), 0.0)

    # ---- Stage 2: affine transform --------------------------------------
    a_coef = gamma * std_inv
    out_ref[...] = a_coef * (d - mean) + beta


@functools.partial(jax.jit, static_argnames=("zp", "block_rows", "interpret"))
def ailayernorm(
    codes: jnp.ndarray,
    alpha: jnp.ndarray,
    gamma: jnp.ndarray,
    beta: jnp.ndarray,
    *,
    zp: int = 128,
    block_rows: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """AILayerNorm over the last axis of PTF-quantized ``codes``.

    Args:
      codes: (..., C) u8 codes as f32 (PTF-quantized LayerNorm input).
      alpha: (C,) power-of-two factors (integer-valued f32).
      gamma, beta: (C,) affine parameters.
      zp: layer-wise zero point.

    Returns:
      (..., C) f32 normalized output, on the shared integer domain D
      (the layer scale s cancels in (x - mu)/sigma — DESIGN.md §6).
    """
    orig_shape = codes.shape
    cdim = orig_shape[-1]
    rows = 1
    for dim in orig_shape[:-1]:
        rows *= dim
    x2 = codes.reshape(rows, cdim).astype(jnp.float32)
    pad = (-rows) % block_rows
    if pad:
        # pad rows with zp codes -> var 0 -> std_inv 0, harmless
        x2 = jnp.concatenate([x2, jnp.full((pad, cdim), float(zp), jnp.float32)], axis=0)
    grid = (x2.shape[0] // block_rows,)
    kern = functools.partial(_ailayernorm_kernel, zp=zp, cdim=cdim)
    a2 = alpha.reshape(1, cdim).astype(jnp.float32)
    g2 = gamma.reshape(1, cdim).astype(jnp.float32)
    b2 = beta.reshape(1, cdim).astype(jnp.float32)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cdim), lambda i: (i, 0)),
            pl.BlockSpec((1, cdim), lambda i: (0, 0)),
            pl.BlockSpec((1, cdim), lambda i: (0, 0)),
            pl.BlockSpec((1, cdim), lambda i: (0, 0)),
            pl.BlockSpec((1, 64), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cdim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x2.shape[0], cdim), jnp.float32),
        interpret=interpret,
    )(x2, a2, g2, b2, _RSQRT_TABLE.reshape(1, 64))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)


def vmem_bytes(block_rows: int, cdim: int) -> dict:
    """Static VMEM footprint model of one grid step (DESIGN.md §7 L1)."""
    r, c = block_rows, cdim
    return {
        "input_codes_8bit": r * c,          # the paper's 8-bit Input Buffer
        "params_f32": 3 * 4 * c,            # alpha/gamma/beta
        "stats_regs": 8 * r,                # E_x / E_x2 accumulators
        "interpret_input_f32": 4 * r * c,
        "total_arch": r * c + 12 * c + 8 * r,
    }
