"""Reference oracles for SOLE's two algorithms.

Three families of functions live here:

1. **Bit-exact integer references** (``*_int``): plain-Python/numpy integer
   implementations of E2Softmax (Algorithm 1) and AILayerNorm (Algorithm 2)
   exactly as the Rust models implement them (DESIGN.md §6).  These produce
   the golden vectors that pin the Rust implementation, and are the oracle
   for the Pallas kernels in the exact-representable regime.

2. **Float "model-path" references** (``*_f``): jnp-free numpy float
   implementations of the same algorithms in the two-pass formulation used
   inside the JAX models for the accuracy experiments (Tables I/II).

3. **Exact baselines**: IEEE softmax / layernorm, plus the Softermax and
   I-BERT approximations used as accuracy baselines.

Everything is deterministic and dependency-free (numpy only).
"""

from __future__ import annotations

import math

import numpy as np

# ---------------------------------------------------------------------------
# Fixed-point configuration (the contract constants — keep in sync with
# rust/src/softmax/config.rs and rust/src/layernorm/config.rs)
# ---------------------------------------------------------------------------

LOG2EXP_F = 8  # internal fraction bits of the Log2Exp shift-add datapath
K_MAX = 15  # 4-bit log2-quantized exponent output
SUM_FRAC = 15  # Q(.15) online sum accumulator
ALDIV_Q = 23  # Q(.23) constants 1.636 / 1.136 (fit f32 exact-int range)
ALDIV_C0 = round(1.636 * (1 << ALDIV_Q))  # s' = 0
ALDIV_C1 = round(1.136 * (1 << ALDIV_Q))  # s' = 1
OUT_FRAC = 8  # 8-bit softmax output, scale 2^-8
RSQRT_LUT_BITS = 6  # 64-entry x^-0.5 LUT
RSQRT_LUT_Q = 16  # Q(.16) LUT entries
DEFAULT_E = 4  # default power-of-two input scale 2^-e for softmax inputs


# ---------------------------------------------------------------------------
# Log2Exp — Eq. (7)/(8): k = clip(round(-x/ln2), 0, 15) via x + x>>1 - x>>4
# ---------------------------------------------------------------------------

def log2exp_int(d: int, e: int = DEFAULT_E, f: int = LOG2EXP_F) -> int:
    """Bit-exact Log2Exp on an integer code difference ``d <= 0``.

    ``d`` is (input code - running max code) with input scale 2^-e, so the
    real-valued argument is x = d * 2^-e.  Returns k in [0, 15] such that
    exp(x) ~ 2^-k.  Shifts are arithmetic (floor), matching hardware.
    """
    assert d <= 0, "Log2Exp domain is (-inf, 0]"
    v = d << f
    t = v + (v >> 1) - (v >> 4)  # v * 1.4375 with floor shifts
    # round-half-up of (-t) / 2^(f+e); -t >= 0
    k = (-t + (1 << (f + e - 1))) >> (f + e)
    return min(k, K_MAX)


def log2exp_f(d: np.ndarray, e: int = DEFAULT_E, f: int = LOG2EXP_F) -> np.ndarray:
    """Vectorized float twin of :func:`log2exp_int` (int-valued float I/O).

    ``d`` holds integer-valued code differences <= 0.  Floor-shifts on
    negative integers are reproduced with np.floor, so this matches the
    integer version exactly wherever the float mantissa suffices.
    """
    v = d * float(1 << f)
    t = v + np.floor(v * 0.5) - np.floor(v * 0.0625)
    k = np.floor((-t + float(1 << (f + e - 1))) / float(1 << (f + e)))
    return np.minimum(k, float(K_MAX))


# ---------------------------------------------------------------------------
# ALDivision — Eq. (13)/(17)
# ---------------------------------------------------------------------------

def aldivision_int(k_y: int, sum_q15: int) -> tuple[int, int]:
    """Bit-exact approximate log-based division.

    ``k_y``: log2-domain numerator (>= 0); ``sum_q15``: the online reduced
    sum in Q(.15) (> 0).  Returns ``(out_q23, out_u8)``: the Q(.24)
    fixed-point quotient and its 8-bit output code (scale 2^-8).
    """
    assert sum_q15 > 0
    msb = sum_q15.bit_length() - 1
    k_s = msb - SUM_FRAC
    s1 = (sum_q15 >> (msb - 1)) & 1 if msb >= 1 else 0
    shift = k_y + k_s + 1
    c = ALDIV_C1 if s1 else ALDIV_C0
    out_q23 = c >> shift if 0 <= shift < 64 else (c << -shift if shift < 0 else 0)
    # round-half-up to 8-bit output code
    code = (out_q23 + (1 << (ALDIV_Q - OUT_FRAC - 1))) >> (ALDIV_Q - OUT_FRAC)
    return out_q23, min(code, 255)


# ---------------------------------------------------------------------------
# E2Softmax — Algorithm 1 (online, bit-exact)
# ---------------------------------------------------------------------------

def e2softmax_online_int(q, e: int = DEFAULT_E, chunk: int = 1) -> dict:
    """Bit-exact single-pass E2Softmax over one row of integer codes ``q``.

    ``chunk=1`` follows Algorithm 1 exactly: running max, Log2Exp of the
    delta, online sum rescaling by ``sum >> Log2Exp(m_prev - m_new)``, then
    stage 2 correction + ALDivision.  ``chunk=V`` models the V-lane unit
    (the paper's vector size is 32): each slice takes a local max via the
    comparison tree, the running max/sum update once per slice, and every
    element of the slice is referenced to that slice's running max.
    Returns a dict with every intermediate so the golden tests can pin
    each stage.
    """
    q = [int(v) for v in np.asarray(q).ravel()]
    n = len(q)
    assert n >= 1 and chunk >= 1
    m_prev: int | None = None
    s = 0
    ks: list[int] = []
    ms: list[int] = []
    for c0 in range(0, n, chunk):
        sl = q[c0:c0 + chunk]
        local = max(sl)
        m_new = local if m_prev is None else max(local, m_prev)
        if m_prev is not None and m_prev != m_new:
            sub = log2exp_int(m_prev - m_new, e)
            s >>= sub
        for qi in sl:
            k_i = log2exp_int(qi - m_new, e)
            s += 1 << (SUM_FRAC - k_i)
            ks.append(k_i)
            ms.append(m_new)
        m_prev = m_new
    m_final = m_prev
    out_q23 = []
    out_u8 = []
    kys = []
    for i in range(n):
        sub = log2exp_int(ms[i] - m_final, e)
        k_y = ks[i] + sub
        o23, o8 = aldivision_int(k_y, s)
        kys.append(k_y)
        out_q23.append(o23)
        out_u8.append(o8)
    return {
        "k": ks,
        "running_max": ms,
        "sum_q15": s,
        "k_y": kys,
        "out_q23": out_q23,
        "out_u8": out_u8,
        "out_f": [v / float(1 << ALDIV_Q) for v in out_q23],
    }


def e2softmax_twopass_f(x: np.ndarray, e: int = DEFAULT_E, quantize_out: bool = False) -> np.ndarray:
    """Two-pass float E2Softmax over the last axis (the model/accuracy path).

    ``x`` is real-valued (e.g. attention logits).  Codes are formed as
    d = clip(round((x - max) * 2^e), -255, 0); the exponent output is
    log2-quantized to 4 bits and the division is the unbiased ALDivision.
    """
    x = np.asarray(x, dtype=np.float64)
    xmax = x.max(axis=-1, keepdims=True)
    d = np.clip(np.round((x - xmax) * float(1 << e)), -255.0, 0.0)
    k = log2exp_f(d, e)
    p = np.power(2.0, -k)
    ssum = p.sum(axis=-1, keepdims=True)
    k_s = np.floor(np.log2(ssum))
    frac = ssum / np.power(2.0, k_s) - 1.0  # in [0, 1)
    s1 = (frac >= 0.5).astype(np.float64)
    c = 1.636 - 0.5 * s1
    out = c * np.power(2.0, -(k + k_s + 1.0))
    if quantize_out:
        out = np.clip(np.round(out * 256.0), 0.0, 255.0) / 256.0
    return out


# ---------------------------------------------------------------------------
# Dynamic compression + AILayerNorm — Algorithm 2 (bit-exact)
# ---------------------------------------------------------------------------

def dynamic_compress_int(x: int) -> tuple[int, int]:
    """8-bit magnitude -> (4-bit code y, 1-bit shift-select s).

    Recovery is x ~ y << (2 + 2s): values >= 64 keep their top nibble
    (s=1, shift 4), smaller values keep bits [5:2] (s=0, shift 2).
    Rounding is to-nearest (add half-LSB before the bit-select): truncation
    would bias E(x^2) by ~8%, while the paper claims ~0.2% — only the
    rounding variant meets that, at the cost of one carry adder.
    """
    assert 0 <= x <= 255
    if x >= 64:
        return min((x + 8) >> 4, 15), 1
    return min((x + 2) >> 2, 15), 0


SQUARE_LUT = [y * y for y in range(16)]  # the 16-entry square LUT


def rsqrt_lut() -> list[int]:
    """The 64-entry x^-0.5 LUT: Q(.16) entries of 1/sqrt(v), v in [1,4)."""
    out = []
    for i in range(1 << RSQRT_LUT_BITS):
        v = 1.0 + (i + 0.5) * 3.0 / (1 << RSQRT_LUT_BITS)
        out.append(round((1 << RSQRT_LUT_Q) / math.sqrt(v)))
    return out


_RSQRT_LUT = rsqrt_lut()


def rsqrt_hw(var_num: int, var_den: int) -> float:
    """Hardware x^-0.5: normalize var = var_num/var_den to 4^k * v with
    v in [1,4), look up 1/sqrt(v) in the 64-entry Q16 LUT, shift by k.

    Exact-rational normalization (var_num, var_den ints) keeps this
    bit-reproducible across languages.
    """
    assert var_num > 0 and var_den > 0
    k = 0
    num, den = var_num, var_den
    while num >= 4 * den:
        den *= 4
        k += 1
    while num < den:
        num *= 4
        k -= 1
    # v = var / 4^k in [1,4); LUT index floor((v-1) * 64 / 3)
    idx = ((num - den) * (1 << RSQRT_LUT_BITS)) // (3 * den)
    idx = min(idx, (1 << RSQRT_LUT_BITS) - 1)
    return _RSQRT_LUT[idx] / float(1 << RSQRT_LUT_Q) * math.pow(2.0, -k)


def ailayernorm_int(
    x_codes: np.ndarray,
    alpha: np.ndarray,
    zp: int,
    gamma: np.ndarray,
    beta: np.ndarray,
) -> dict:
    """Bit-exact AILayerNorm over one row (C channels) of u8 codes.

    Statistics are computed exactly as the hardware does: signed codes
    D_i = (X_i - zp) << alpha_i accumulate E_x; magnitudes are
    dynamically compressed, squared via the 16-entry LUT, decompressed
    by << 4s, PTF-shifted by << 2*alpha, and the reduced sum picks up the
    deferred << 4 (DESIGN.md §2 erratum note).  The affine stage is float
    (gamma/beta/std_inv), matching the unit's Preprocess/Affine split.
    """
    x_codes = np.asarray(x_codes).ravel()
    alpha = np.asarray(alpha).ravel()
    c = len(x_codes)
    assert len(alpha) == c
    ex = 0
    ex2 = 0
    d_all = []
    comp = []
    for i in range(c):
        xi = int(x_codes[i]) - zp
        a = int(alpha[i])
        d = xi << a
        ex += d
        mag = min(abs(xi), 255)
        y, sflag = dynamic_compress_int(mag)
        sq = SQUARE_LUT[y] << (4 * sflag)  # decompress: x^2 ~ y^2 << 4s (<<4 deferred)
        ex2 += sq << (2 * a)
        d_all.append(d)
        comp.append((y, sflag))
    ex2 <<= 4  # deferred common shift
    # var = E[x^2] - E[x]^2 as an exact rational with denominator C^2
    var_num = ex2 * c - ex * ex
    mean = ex / c
    if var_num <= 0:
        std_inv = 0.0
        var = 0.0
    else:
        var = var_num / (c * c)
        std_inv = rsqrt_hw(var_num, c * c)
    gamma = np.asarray(gamma, dtype=np.float64).ravel()
    beta = np.asarray(beta, dtype=np.float64).ravel()
    a_coef = gamma * std_inv
    y_out = a_coef * (np.array(d_all, dtype=np.float64) - mean) + beta
    return {
        "d": d_all,
        "compressed": comp,
        "ex": ex,
        "ex2": ex2,
        "mean": mean,
        "var": var,
        "std_inv": std_inv,
        "y": y_out,
    }


def ailayernorm_f(
    x: np.ndarray,
    alpha: np.ndarray,
    s: float,
    zp: int,
    gamma: np.ndarray,
    beta: np.ndarray,
    lut_rsqrt: bool = False,
) -> np.ndarray:
    """Float model-path AILayerNorm over the last axis of real-valued ``x``.

    Quantizes with PTF (scale s * 2^alpha, zero point zp), runs the
    approximate statistics, and applies the affine transform.  The layer
    scale ``s`` cancels in (x - mu)/sigma, so the math matches
    :func:`ailayernorm_int` on the shared integer domain.
    """
    x = np.asarray(x, dtype=np.float64)
    alpha = np.asarray(alpha, dtype=np.float64)
    cdim = x.shape[-1]
    scale = s * np.power(2.0, alpha)
    codes = np.clip(np.round(x / scale) + zp, 0, 255)
    xi = codes - zp
    d = xi * np.power(2.0, alpha)
    mag = np.minimum(np.abs(xi), 255.0)
    sflag = (mag >= 64.0).astype(np.float64)
    y4 = np.minimum(np.where(sflag > 0, np.floor((mag + 8.0) / 16.0),
                             np.floor((mag + 2.0) / 4.0)), 15.0)
    sq = (y4 * y4) * np.power(2.0, 4.0 * sflag) * np.power(2.0, 2.0 * alpha)
    ex = d.mean(axis=-1, keepdims=True)
    ex2 = sq.sum(axis=-1, keepdims=True) * 16.0 / cdim
    var = np.maximum(ex2 - ex * ex, 0.0)
    if lut_rsqrt:
        k = np.floor(np.floor(np.log2(np.maximum(var, 1e-30))) / 2.0)
        v = var / np.power(4.0, k)
        idx = np.minimum(np.floor((v - 1.0) * (1 << RSQRT_LUT_BITS) / 3.0), (1 << RSQRT_LUT_BITS) - 1)
        lut = np.array(_RSQRT_LUT, dtype=np.float64) / float(1 << RSQRT_LUT_Q)
        std_inv = lut[idx.astype(np.int64)] * np.power(2.0, -k)
    else:
        std_inv = np.where(var > 0, 1.0 / np.sqrt(np.maximum(var, 1e-30)), 0.0)
    return gamma * (d - ex) * std_inv + beta


# ---------------------------------------------------------------------------
# Exact + prior-work baselines
# ---------------------------------------------------------------------------

def softmax_exact(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    z = x - x.max(axis=-1, keepdims=True)
    p = np.exp(z)
    return p / p.sum(axis=-1, keepdims=True)


def layernorm_exact(x: np.ndarray, gamma: np.ndarray, beta: np.ndarray, eps: float = 1e-6) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return gamma * (x - mu) / np.sqrt(var + eps) + beta


def softermax_f(x: np.ndarray, frac_bits: int = 8) -> np.ndarray:
    """Softermax (Stevens et al., DAC'21) functional model: base-2 softmax
    with low-precision (2^-frac_bits) un-normalized intermediates."""
    x = np.asarray(x, dtype=np.float64)
    z = np.floor(x / math.log(2.0) * (1 << frac_bits)) / (1 << frac_bits)
    z = z - np.ceil(z.max(axis=-1, keepdims=True))
    p = np.power(2.0, z)
    q = np.floor(p * (1 << frac_bits)) / (1 << frac_bits)  # 16-bit-ish storage
    s = q.sum(axis=-1, keepdims=True)
    return q / np.where(s > 0, s, 1.0)


def ibert_softmax_f(x: np.ndarray, scale: float = 1.0 / 16) -> np.ndarray:
    """I-BERT i-exp softmax (Kim et al., ICML'21) functional model.

    exp(p) on p in (-ln2, 0] is approximated by the integer polynomial
    0.3585 (p + 1.353)^2 + 0.344 after range reduction x = -z ln2 + p.
    All quantities follow the integer pipeline at input scale ``scale``.
    """
    x = np.asarray(x, dtype=np.float64)
    q = np.floor(x / scale)
    q = q - q.max(axis=-1, keepdims=True)
    ln2_q = np.floor(math.log(2.0) / scale)
    z = np.floor(-q / ln2_q)
    p = q + z * ln2_q  # in (-ln2/scale, 0]
    b, c = 1.353, 0.344
    a = 0.3585
    qb = np.floor(b / scale)
    qc = np.floor(c / (a * scale * scale))
    qout = (p + qb) ** 2 + qc  # at scale a*scale^2
    qexp = np.floor(qout / np.power(2.0, z))
    s = qexp.sum(axis=-1, keepdims=True)
    return qexp / np.where(s > 0, s, 1.0)


def ibert_layernorm_f(x: np.ndarray, gamma: np.ndarray, beta: np.ndarray, scale: float = 1.0 / 64) -> np.ndarray:
    """I-BERT integer LayerNorm (also the arithmetic half of the NN-LUT
    baseline): INT32 statistics on quantized codes + integer sqrt."""
    x = np.asarray(x, dtype=np.float64)
    q = np.floor(x / scale)
    mu = np.floor(q.mean(axis=-1, keepdims=True))
    dv = q - mu
    var = np.floor((dv * dv).mean(axis=-1, keepdims=True))
    std = np.floor(np.sqrt(var)) + 1.0
    return gamma * dv / std + beta


__all__ = [
    "LOG2EXP_F", "K_MAX", "SUM_FRAC", "ALDIV_Q", "ALDIV_C0", "ALDIV_C1",
    "OUT_FRAC", "RSQRT_LUT_BITS", "RSQRT_LUT_Q", "DEFAULT_E",
    "log2exp_int", "log2exp_f", "aldivision_int",
    "e2softmax_online_int", "e2softmax_twopass_f",
    "dynamic_compress_int", "SQUARE_LUT", "rsqrt_lut", "rsqrt_hw",
    "ailayernorm_int", "ailayernorm_f",
    "softmax_exact", "layernorm_exact",
    "softermax_f", "ibert_softmax_f", "ibert_layernorm_f",
]
