"""Pallas kernel vs ref.py — the core L1 correctness signal.

Hypothesis sweeps shapes/seeds; bit-exact assertions in the
mantissa-exact regime (L <= 512 for E2Softmax), tolerance assertions
beyond it.  interpret=True throughout (CPU).
"""

import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp  # noqa: E402

from compile.kernels import ref  # noqa: E402
from compile.kernels import e2softmax as e2  # noqa: E402
from compile.kernels import ailayernorm as ail  # noqa: E402


def _codes(x_row: np.ndarray, e: int = 4) -> np.ndarray:
    return np.clip(np.round((x_row - x_row.max()) * (1 << e)), -255, 0).astype(int)


class TestE2SoftmaxKernel:
    @given(
        rows=st.integers(min_value=1, max_value=6),
        nchunks=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        scale=st.sampled_from([0.5, 2.0, 8.0]),
    )
    @settings(max_examples=25, deadline=None)
    def test_bitexact_vs_ref(self, rows, nchunks, seed, scale):
        v = 32
        length = v * nchunks
        rng = np.random.default_rng(seed)
        x = rng.normal(0, scale, (rows, length)).astype(np.float32)
        probs, codes = e2.e2softmax(jnp.array(x), v=v, block_rows=4)
        probs = np.asarray(probs)
        codes = np.asarray(codes)
        for r in range(rows):
            gold = ref.e2softmax_online_int(_codes(x[r]), chunk=v)
            np.testing.assert_array_equal(np.array(gold["out_f"]), probs[r])
            np.testing.assert_array_equal(
                np.array(gold["out_u8"], dtype=np.float32), codes[r])

    def test_lane_width_16(self):
        rng = np.random.default_rng(0)
        x = rng.normal(0, 2, (3, 48)).astype(np.float32)
        probs, _ = e2.e2softmax(jnp.array(x), v=16)
        for r in range(3):
            gold = ref.e2softmax_online_int(_codes(x[r]), chunk=16)
            np.testing.assert_array_equal(np.array(gold["out_f"]), np.asarray(probs)[r])

    def test_large_row_tolerance(self):
        """L = 1024 exceeds the f32-exact sum regime; the result may land on
        a neighbouring quantization step but stays within 1% of ref."""
        rng = np.random.default_rng(1)
        x = rng.normal(0, 2, (2, 1024)).astype(np.float32)
        probs, _ = e2.e2softmax(jnp.array(x), v=32)
        for r in range(2):
            gold = np.array(ref.e2softmax_online_int(_codes(x[r]), chunk=32)["out_f"])
            p = np.asarray(probs)[r]
            mask = gold > 0
            assert np.abs(p[mask] / gold[mask] - 1).max() < 0.01

    def test_batch_dims_preserved(self):
        rng = np.random.default_rng(2)
        x = rng.normal(0, 1, (2, 3, 5, 64)).astype(np.float32)
        probs, codes = e2.e2softmax(jnp.array(x))
        assert probs.shape == x.shape and codes.shape == x.shape
        flat, _ = e2.e2softmax(jnp.array(x.reshape(-1, 64)))
        np.testing.assert_array_equal(np.asarray(probs).reshape(-1, 64), np.asarray(flat))

    def test_rejects_bad_length(self):
        with pytest.raises(ValueError):
            e2.e2softmax(jnp.zeros((2, 33)), v=32)

    def test_row_sum_near_one(self):
        rng = np.random.default_rng(3)
        x = rng.normal(0, 2, (16, 128)).astype(np.float32)
        probs, _ = e2.e2softmax(jnp.array(x))
        sums = np.asarray(probs).sum(-1)
        assert np.all(sums > 0.6) and np.all(sums < 1.5)


class TestAILayerNormKernel:
    @given(
        rows=st.integers(min_value=1, max_value=6),
        cdim=st.sampled_from([16, 64, 192, 384]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        amax=st.integers(min_value=0, max_value=5),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_int_ref(self, rows, cdim, seed, amax):
        rng = np.random.default_rng(seed)
        codes = rng.integers(0, 256, size=(rows, cdim))
        alpha = rng.integers(0, amax + 1, size=cdim)
        gamma = rng.normal(1, 0.2, cdim)
        beta = rng.normal(0, 0.2, cdim)
        out = np.asarray(ail.ailayernorm(
            jnp.array(codes, dtype=jnp.float32), jnp.array(alpha, dtype=jnp.float32),
            jnp.array(gamma, dtype=jnp.float32), jnp.array(beta, dtype=jnp.float32),
            zp=128, block_rows=4))
        for r in range(rows):
            gold = ref.ailayernorm_int(codes[r], alpha, 128, gamma, beta)
            scale = max(1.0, np.abs(gold["y"]).max())
            assert np.abs(gold["y"] - out[r]).max() / scale < 1e-4

    def test_constant_row(self):
        """var = 0 -> std_inv = 0 -> output = beta."""
        c = 32
        codes = jnp.full((2, c), 130.0)
        out = np.asarray(ail.ailayernorm(
            codes, jnp.zeros(c), jnp.ones(c), jnp.full(c, 0.25), zp=128))
        np.testing.assert_allclose(out, 0.25, atol=1e-6)

    def test_rsqrt_lut_matches_ref(self):
        rng = np.random.default_rng(4)
        vars_ = rng.uniform(0.5, 1e9, 200).astype(np.float32)
        got = np.asarray(ail.rsqrt_lut_f(jnp.array(vars_)))
        for v, g in zip(vars_, got):
            num, den = int(np.float64(v) * 2**20), 2**20
            expect = ref.rsqrt_hw(num, den)
            assert abs(g / expect - 1) < 2e-3

    def test_batch_dims_preserved(self):
        rng = np.random.default_rng(5)
        codes = rng.integers(0, 256, size=(2, 4, 64)).astype(np.float32)
        c = 64
        out = ail.ailayernorm(jnp.array(codes), jnp.zeros(c), jnp.ones(c),
                              jnp.zeros(c), zp=128)
        assert out.shape == codes.shape


class TestModelIntegration:
    """The kernels inside a jitted forward (the path AOT lowers)."""

    def test_sole_forward_runs_and_tracks_exact(self):
        import jax
        from compile.model import MODEL_ZOO, OpsConfig, EXACT, forward, init_params
        from compile import calibrate

        cfg = MODEL_ZOO["deit_t"]
        params = init_params(cfg, seed=0)
        rng = np.random.default_rng(0)
        x = jnp.array(rng.normal(0, 1, (2, 32, 32, 1)).astype(np.float32))
        ln_calib = calibrate.ptf_calibrate(params, x, cfg)
        ops = OpsConfig(softmax="sole", layernorm="sole", ln_calib=ln_calib)
        exact = np.asarray(jax.jit(lambda a: forward(params, a, cfg, EXACT))(x))
        sole = np.asarray(jax.jit(lambda a: forward(params, a, cfg, ops))(x))
        assert sole.shape == exact.shape
        assert np.isfinite(sole).all()
        # logits stay correlated — SOLE is an approximation, not noise
        cc = np.corrcoef(exact.ravel(), sole.ravel())[0, 1]
        assert cc > 0.95
