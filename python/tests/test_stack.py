"""Build-time stack tests: datasets, tensor bundles, params (de)serialization,
PTF calibration, and the prior-work jnp twins inside the model."""

import sys
import tempfile
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp  # noqa: E402

from compile import calibrate, data, tensor_io, train  # noqa: E402
from compile.model import (  # noqa: E402
    EXACT, MODEL_ZOO, OpsConfig, bert_for_task, forward, init_params,
)


class TestData:
    def test_deterministic(self):
        a = data.shapes_dataset(32, seed=1)
        b = data.shapes_dataset(32, seed=1)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_shapes(self):
        x, y = data.shapes_dataset(16, seed=2)
        assert x.shape == (16, 32, 32, 1) and x.dtype == np.float32
        assert y.shape == (16,) and set(y) <= set(range(10))

    def test_tokens_all_tasks(self):
        for task in data.NLP_TASKS:
            x, y = data.tokens_dataset(task, 64, seed=3)
            assert x.shape == (64, data.SEQ_LEN)
            assert x.min() >= 0 and x.max() < data.VOCAB
            ncls = data.task_num_classes(task)
            assert y.min() >= 0 and y.max() < ncls

    def test_labels_learnable_not_constant(self):
        """Every task must have both labels present (non-degenerate)."""
        for task in data.NLP_TASKS:
            _, y = data.tokens_dataset(task, 256, seed=4)
            assert len(np.unique(y)) >= 2, task


class TestTensorIO:
    def test_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            stem = Path(d) / "bundle"
            tensors = {
                "a": np.arange(12, dtype=np.float32).reshape(3, 4),
                "b/c": np.array([1, 2, 3], dtype=np.int32),
                "u": np.arange(8, dtype=np.uint8),
            }
            tensor_io.write_bundle(stem, tensors)
            back = tensor_io.read_bundle(stem)
            assert set(back) == set(tensors)
            for k in tensors:
                np.testing.assert_array_equal(back[k], tensors[k])

    def test_f64_downcast(self):
        with tempfile.TemporaryDirectory() as d:
            stem = Path(d) / "b"
            tensor_io.write_bundle(stem, {"x": np.ones(3, dtype=np.float64)})
            assert tensor_io.read_bundle(stem)["x"].dtype == np.float32


class TestParamsRoundtrip:
    def test_flatten_unflatten(self):
        cfg = MODEL_ZOO["deit_t"]
        p = init_params(cfg, seed=7)
        flat = train._flatten(p)
        back = train._unflatten(flat)
        x = jnp.zeros((1, 32, 32, 1))
        a = np.asarray(forward(p, x, cfg, EXACT))
        b = np.asarray(forward(back, x, cfg, EXACT))
        np.testing.assert_array_equal(a, b)

    def test_save_load(self):
        cfg = bert_for_task(2)
        p = init_params(cfg, seed=8)
        with tempfile.TemporaryDirectory() as d:
            stem = Path(d) / "w"
            train.save_params(stem, p)
            q = train.load_params(stem)
        x = jnp.zeros((2, cfg.seq_len), dtype=jnp.int32)
        np.testing.assert_allclose(np.asarray(forward(p, x, cfg, EXACT)),
                                   np.asarray(forward(q, x, cfg, EXACT)), rtol=1e-6)


class TestCalibration:
    def test_ptf_covers_all_lns(self):
        cfg = MODEL_ZOO["deit_t"]
        p = init_params(cfg, seed=9)
        x = jnp.array(np.random.default_rng(0).normal(0, 1, (4, 32, 32, 1)),
                      dtype=jnp.float32)
        cal = calibrate.ptf_calibrate(p, x, cfg)
        expect = {f"b{i}.ln{j}" for i in range(cfg.depth) for j in (1, 2)} | {"lnf"}
        assert set(cal) == expect
        for entry in cal.values():
            assert len(entry["alpha"]) == cfg.dim
            assert entry["s"] > 0
            assert all(0 <= a <= calibrate.ALPHA_MAX for a in entry["alpha"])

    def test_outlier_channel_gets_larger_alpha(self):
        cfg = MODEL_ZOO["deit_t"]
        p = init_params(cfg, seed=10)
        # inflate one channel of ln gamma path via pos_emb
        p["pos_emb"] = p["pos_emb"].at[:, 5].mul(50.0)
        x = jnp.array(np.random.default_rng(1).normal(0, 1, (4, 32, 32, 1)),
                      dtype=jnp.float32)
        cal = calibrate.ptf_calibrate(p, x, cfg)
        a = np.array(cal["b0.ln1"]["alpha"])
        assert a[5] >= np.median(a)


class TestModelVariants:
    @pytest.mark.parametrize("softmax", ["exact", "softermax", "ibert"])
    def test_softmax_variants_finite(self, softmax):
        cfg = bert_for_task(2)
        p = init_params(cfg, seed=11)
        x = jnp.array(np.random.default_rng(2).integers(0, cfg.vocab, (2, cfg.seq_len)),
                      dtype=jnp.int32)
        out = np.asarray(forward(p, x, cfg, OpsConfig(softmax=softmax)))
        assert np.isfinite(out).all()

    def test_int8_close_to_fp32(self):
        cfg = MODEL_ZOO["deit_t"]
        p = init_params(cfg, seed=12)
        x = jnp.array(np.random.default_rng(3).normal(0, 1, (2, 32, 32, 1)),
                      dtype=jnp.float32)
        a = np.asarray(forward(p, x, cfg, EXACT))
        b = np.asarray(forward(p, x, cfg, OpsConfig(matmul="int8")))
        assert np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.99

    def test_swin_windows(self):
        cfg = MODEL_ZOO["swin_t"]
        p = init_params(cfg, seed=13)
        x = jnp.array(np.random.default_rng(4).normal(0, 1, (2, 32, 32, 1)),
                      dtype=jnp.float32)
        out = np.asarray(forward(p, x, cfg, EXACT))
        assert out.shape == (2, cfg.n_classes)
        assert np.isfinite(out).all()
