"""Unit + property tests for the reference oracles (kernels/ref.py).

These pin the paper's numeric claims:
  - Eq. 8  : Log2Exp shift-add == round(-x/ln2) within 1 step (approx 1.4375)
  - Eq. 13 : ALDivision is the unbiased variant (E[err] ~ 0 over uniform s)
  - Eq. 17 : divider output constants 0.818 / 0.568
  - SqIII-C: dynamic compression error ~0.2% on E(x^2), ~0.4% on sigma
             for uniform inputs
"""

import math
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.kernels import ref  # noqa: E402


# ---------------------------------------------------------------------------
# Log2Exp
# ---------------------------------------------------------------------------

class TestLog2Exp:
    def test_zero(self):
        assert ref.log2exp_int(0) == 0

    def test_saturation(self):
        assert ref.log2exp_int(-255) == 15
        assert ref.log2exp_int(-200, e=4) == 15

    @given(st.integers(min_value=-255, max_value=0), st.integers(min_value=3, max_value=6))
    @settings(max_examples=300, deadline=None)
    def test_matches_ideal(self, d, e):
        """Shift-add 1.4375 approx of 1/ln2=1.4427 stays within 1 of ideal."""
        k = ref.log2exp_int(d, e)
        ideal = min(max(round(-d * 2.0 ** (-e) / math.log(2)), 0), 15)
        assert abs(k - ideal) <= 1

    @given(st.integers(min_value=-255, max_value=0), st.integers(min_value=3, max_value=6))
    @settings(max_examples=300, deadline=None)
    def test_float_twin_exact(self, d, e):
        kf = ref.log2exp_f(np.array([float(d)]), e)[0]
        assert kf == ref.log2exp_int(d, e)

    def test_monotone(self):
        ks = [ref.log2exp_int(d) for d in range(0, -256, -1)]
        assert all(a <= b for a, b in zip(ks, ks[1:]))


# ---------------------------------------------------------------------------
# ALDivision
# ---------------------------------------------------------------------------

class TestALDivision:
    def test_eq17_constants(self):
        # k_y = 0, sum = 2^15 (s'=0): out = 1.636/2 = 0.818
        o23, _ = ref.aldivision_int(0, 1 << 15)
        assert abs(o23 / (1 << 23) - 0.818) < 1e-3
        # s' = 1: out = 1.136/2 = 0.568
        o23, _ = ref.aldivision_int(0, (1 << 15) | (1 << 14))
        assert abs(o23 / (1 << 23) - 0.568) < 1e-3

    def test_unbiased(self):
        """Mean relative error vs exact division ~ 0 (the -0.636/2 fix)."""
        rng = np.random.default_rng(3)
        rel = []
        for _ in range(4000):
            k_y = int(rng.integers(0, 8))
            s = int(rng.integers(1 << 15, 1 << 20))
            o23, _ = ref.aldivision_int(k_y, s)
            exact = 2.0 ** (-k_y) / (s / 2 ** 15)
            rel.append(o23 / (1 << 23) / exact - 1.0)
        assert abs(np.mean(rel)) < 0.03
        assert np.max(np.abs(rel)) < 0.25  # Mitchell-style bounded error

    @given(st.integers(min_value=0, max_value=30),
           st.integers(min_value=1 << 15, max_value=1 << 26))
    @settings(max_examples=300, deadline=None)
    def test_code_consistent(self, k_y, s):
        o23, o8 = ref.aldivision_int(k_y, s)
        assert 0 <= o8 <= 255
        # code is round-half-up of the Q23 value to 8 bits
        expect = min((o23 + (1 << 14)) >> 15, 255)
        assert o8 == expect


# ---------------------------------------------------------------------------
# E2Softmax end-to-end properties
# ---------------------------------------------------------------------------

class TestE2Softmax:
    @given(st.lists(st.integers(min_value=-255, max_value=0), min_size=1, max_size=200),
           st.sampled_from([1, 32]))
    @settings(max_examples=150, deadline=None)
    def test_outputs_in_range(self, q, chunk):
        out = ref.e2softmax_online_int(np.array(q), chunk=chunk)
        assert all(0.0 <= v <= 0.818 + 1e-9 for v in out["out_f"])
        assert all(0 <= k <= 15 for k in out["k"])
        assert out["sum_q15"] >= 1 << 15  # global max contributes 2^0

    @given(st.lists(st.integers(min_value=-200, max_value=0), min_size=2, max_size=100))
    @settings(max_examples=100, deadline=None)
    def test_order_preserved(self, q):
        """Softmax is monotone up to one quantization step: the online
        scheme rounds k_i and the stage-2 correction separately (both
        saturating at 15), so single-step inversions are possible and the
        saturated tail (p < ~1e-3) may reorder freely — mirrors the Rust
        monotone_in_input test."""
        out = ref.e2softmax_online_int(np.array(q), chunk=1)
        o = out["out_q23"]
        tail = 1 << 13  # ~1e-3 in Q23
        for i in range(len(q)):
            for j in range(i + 1, len(q)):
                if q[i] > q[j] and o[j] >= tail:
                    assert 2 * o[i] >= o[j], (i, j, o[i], o[j])

    def test_close_to_exact_softmax(self):
        rng = np.random.default_rng(5)
        errs = []
        for _ in range(50):
            x = rng.normal(0, 2, 64)
            p = ref.softmax_exact(x[None, :])[0]
            q = np.clip(np.round((x - x.max()) * 16), -255, 0).astype(int)
            o = np.array(ref.e2softmax_online_int(q, chunk=32)["out_f"])
            errs.append(np.abs(o - p).max())
        # paper: worst-case softmax error small enough for <1% model drop
        assert np.mean(errs) < 0.08

    def test_chunked_equals_flat_when_sorted_desc(self):
        """With a descending row the running max never changes, so chunking
        cannot alter any intermediate."""
        q = np.sort(np.random.default_rng(9).integers(-200, 0, 64))[::-1]
        a = ref.e2softmax_online_int(q, chunk=1)
        b = ref.e2softmax_online_int(q, chunk=32)
        assert a["out_q23"] == b["out_q23"]
        assert a["sum_q15"] == b["sum_q15"]

    def test_twopass_float_matches_online_roughly(self):
        rng = np.random.default_rng(6)
        x = rng.normal(0, 2, (8, 96))
        tp = ref.e2softmax_twopass_f(x)
        for r in range(8):
            q = np.clip(np.round((x[r] - x[r].max()) * 16), -255, 0).astype(int)
            on = np.array(ref.e2softmax_online_int(q, chunk=32)["out_f"])
            # online sum truncation can flip one k_s/s1 step; bounded by 2x
            assert np.abs(on - tp[r]).max() < 0.08


# ---------------------------------------------------------------------------
# Dynamic compression + AILayerNorm
# ---------------------------------------------------------------------------

class TestCompress:
    @given(st.integers(min_value=0, max_value=255))
    @settings(max_examples=256, deadline=None)
    def test_reconstruction_bound(self, x):
        y, s = ref.dynamic_compress_int(x)
        assert 0 <= y <= 15
        rec = y << (2 + 2 * s)
        lsb = 1 << (2 + 2 * s)
        # round-to-nearest: |x - rec| <= lsb/2 except where y clamps at 15
        clamped = (s == 0 and x >= 62) or (s == 1 and x >= 248)
        assert abs(x - rec) <= (lsb if clamped else lsb // 2)

    def test_paper_error_claim_uniform(self):
        """~0.2% error on E(x^2), ~0.4% on sigma for uniform u8 inputs."""
        rng = np.random.default_rng(21)
        xs = rng.integers(0, 256, size=200_000)
        sq_true = (xs.astype(np.float64) ** 2)
        rec = []
        for x in xs:
            y, s = ref.dynamic_compress_int(int(x))
            rec.append(ref.SQUARE_LUT[y] << (4 * s + 4))
        rec = np.array(rec, dtype=np.float64)
        err_ex2 = abs(rec.mean() - sq_true.mean()) / sq_true.mean()
        assert err_ex2 < 0.02  # paper: 0.2%; truncation bias stays O(1%)
        std_true = np.sqrt(sq_true.mean() - xs.mean() ** 2)
        std_rec = np.sqrt(max(rec.mean() - xs.mean() ** 2, 0))
        assert abs(std_rec - std_true) / std_true < 0.02


class TestAILayerNorm:
    def _calibrated(self, rng, c, rows=8, outlier=True):
        x = rng.normal(0, 1, (rows, c))
        if outlier:
            x = x * (1 + 6 * (rng.random(c) > 0.92))
        r_c = np.abs(x).max(0) + 1e-9
        base = max(np.quantile(r_c, 0.1), 1e-9)
        alpha = np.clip(np.round(np.log2(r_c / base)), 0, 5).astype(int)
        s = (r_c / 2.0 ** alpha).max() / 127.0
        return x, alpha, s

    def test_close_to_exact(self):
        rng = np.random.default_rng(31)
        c = 128
        x, alpha, s = self._calibrated(rng, c)
        g = rng.normal(1, 0.1, c)
        b = rng.normal(0, 0.1, c)
        y_ex = ref.layernorm_exact(x, g, b)
        y_ai = ref.ailayernorm_f(x, alpha, s, 128, g, b)
        rms = np.sqrt(((y_ai - y_ex) ** 2).mean()) / np.sqrt((y_ex ** 2).mean())
        assert rms < 0.15

    def test_int_float_agree(self):
        rng = np.random.default_rng(33)
        c = 96
        x, alpha, s = self._calibrated(rng, c)
        g = rng.normal(1, 0.1, c)
        b = rng.normal(0, 0.1, c)
        codes = np.clip(np.round(x / (s * 2.0 ** alpha)) + 128, 0, 255).astype(int)
        for r in range(len(x)):
            gold = ref.ailayernorm_int(codes[r], alpha, 128, g, b)
            yf = ref.ailayernorm_f(x[r:r + 1], alpha.astype(float), s, 128, g, b,
                                   lut_rsqrt=True)[0]
            assert np.abs(gold["y"] - yf).max() < 1e-6

    @given(st.integers(min_value=8, max_value=256))
    @settings(max_examples=40, deadline=None)
    def test_statistics_shift_invariance(self, c):
        """Adding a constant (via zp) must not change the normalized output
        beyond compression effects on magnitudes."""
        rng = np.random.default_rng(c)
        codes = rng.integers(96, 160, size=c)
        alpha = np.zeros(c, dtype=int)
        g = np.ones(c)
        b = np.zeros(c)
        out = ref.ailayernorm_int(codes, alpha, 128, g, b)
        assert abs(float(np.mean(out["y"]))) < 0.3  # normalized: near-zero mean

    def test_rsqrt_lut_accuracy(self):
        rng = np.random.default_rng(41)
        for _ in range(200):
            num = int(rng.integers(1, 1 << 40))
            den = int(rng.integers(1, 1 << 16))
            approx = ref.rsqrt_hw(num, den)
            exact = 1.0 / math.sqrt(num / den)
            assert abs(approx / exact - 1.0) < 0.012  # 64-entry LUT: <1.2%


# ---------------------------------------------------------------------------
# Prior-work baselines sanity (they should also be decent approximations)
# ---------------------------------------------------------------------------

class TestBaselines:
    def test_softermax_close(self):
        rng = np.random.default_rng(51)
        x = rng.normal(0, 2, (16, 64))
        p = ref.softmax_exact(x)
        q = ref.softermax_f(x)
        assert np.abs(p - q).max() < 0.05

    def test_ibert_close(self):
        rng = np.random.default_rng(52)
        x = rng.normal(0, 2, (16, 64))
        p = ref.softmax_exact(x)
        q = ref.ibert_softmax_f(x)
        assert np.abs(p - q).max() < 0.05

    def test_ibert_layernorm_close(self):
        rng = np.random.default_rng(53)
        x = rng.normal(0, 1.5, (16, 64))
        g = np.ones(64)
        b = np.zeros(64)
        a = ref.layernorm_exact(x, g, b)
        c = ref.ibert_layernorm_f(x, g, b)
        assert np.sqrt(((a - c) ** 2).mean()) < 0.1
