//! Network client driver for the TCP front door (DESIGN.md §5.3):
//! connects to a running `sole serve --listen <addr>` process and pushes
//! a mixed inference workload through the wire protocol — round-robin
//! infer requests over `--ops`, optional interleaved decode sessions
//! with explicit `end_session`, an optional chunked-infer row streamed
//! through a `--stream` service (served as `<spec>/stream`), an
//! optional server status fetch, and an optional graceful shutdown
//! request.
//!
//! Typed server rejections (shed, unknown service, …) are counted, not
//! fatal; the process exits nonzero only if *nothing* completed, which
//! is what the CI smoke job asserts on.
//!
//! ```
//! sole serve --listen 127.0.0.1:7411 --ops e2softmax/L128 &
//! cargo run --release --offline --example serve_net -- \
//!     --addr 127.0.0.1:7411 [--requests 64] [--ops e2softmax/L128,...] \
//!     [--decode decode-attention/L64xD32 --decode-steps 8 --sessions 2] \
//!     [--stream consmax/L128 --stream-len 4096 --chunk 64] \
//!     [--status] [--shutdown]
//! ```

use std::time::Duration;

use anyhow::Result;
use sole::coordinator::paper_service_specs;
use sole::ops::OpRegistry;
use sole::server::{NetClient, Reply};
use sole::util::cli::Args;
use sole::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    let addr = args.opt_str("addr", "127.0.0.1:7411");
    let n = args.opt_usize("requests", 64)?;
    let specs: Vec<String> = match args.opt("ops") {
        Some(raw) => raw.split(',').map(|s| s.trim().to_string()).collect(),
        None => paper_service_specs(),
    };
    let decode_spec = args.opt("decode").map(str::to_string);
    let decode_steps = args.opt_usize("decode-steps", 8)?;
    let sessions = args.opt_usize("sessions", 2)?;
    let stream_spec = args.opt("stream").map(str::to_string);
    let stream_len = args.opt_usize("stream-len", 4096)?;
    let chunk = args.opt_usize("chunk", 64)?;

    // derive each spec's item length from the same registry the server
    // built its services from — the wire carries no schema
    let registry = OpRegistry::builtin();
    let mut rng = Rng::new(4242);
    let mut lanes: Vec<(String, Vec<f32>)> = Vec::with_capacity(specs.len());
    for spec in &specs {
        let (parsed, op) = registry.build(spec)?;
        let mut row = vec![0f32; op.item_len()];
        rng.fill_normal(&mut row, 0.0, 2.0);
        lanes.push((parsed.to_string(), row));
    }

    let mut cl = NetClient::connect(addr, Duration::from_secs(30))?;
    println!("connected to {addr}; driving {n} requests over {} services", lanes.len());

    let mut completed = 0u64;
    let mut rejected = 0u64;
    for i in 0..n {
        let (name, row) = &lanes[i % lanes.len()];
        match cl.infer(name, row)? {
            Reply::Output(r) => {
                anyhow::ensure!(!r.output.is_empty(), "empty output from '{name}'");
                completed += 1;
            }
            Reply::Rejected(e) => {
                rejected += 1;
                eprintln!("rejected by {name}: {e}");
            }
            Reply::Text(t) => anyhow::bail!("unexpected text reply to infer: {t}"),
        }
    }

    if let Some(spec) = &decode_spec {
        let (parsed, op) = registry.build(spec)?;
        let name = parsed.to_string();
        let mut item = vec![0f32; op.item_len()];
        println!("decoding {} sessions x {decode_steps} tokens through {name}", sessions.max(1));
        for _step in 0..decode_steps {
            for sid in 0..sessions.max(1) as u64 {
                rng.fill_normal(&mut item, 0.0, 1.0);
                match cl.infer_decode(&name, sid, &item)? {
                    Reply::Output(_) => completed += 1,
                    Reply::Rejected(e) => {
                        rejected += 1;
                        eprintln!("decode rejected (session {sid}): {e}");
                    }
                    Reply::Text(t) => anyhow::bail!("unexpected text reply to decode: {t}"),
                }
            }
        }
        // free the server-side session state explicitly
        for sid in 0..sessions.max(1) as u64 {
            if let Reply::Rejected(e) = cl.end_session(&name, sid)? {
                anyhow::bail!("end_session({sid}) rejected: {e}");
            }
        }
    }

    if let Some(spec) = &stream_spec {
        // chunked infer: the row is longer than any registered L and
        // travels in per-chunk frames to the `<spec>/stream` service
        let parsed = registry.parse_spec(spec)?;
        let name = format!("{parsed}/stream");
        let mut row = vec![0f32; stream_len.max(1)];
        rng.fill_normal(&mut row, 0.0, 2.0);
        let out = cl.stream_row(&name, 1, &row, chunk.max(1))?;
        anyhow::ensure!(
            out.len() == row.len(),
            "streamed {} elements through {name} but got {} back",
            row.len(),
            out.len()
        );
        println!(
            "streamed a {}-element row through {name} in {} chunks",
            row.len(),
            row.len().div_ceil(chunk.max(1))
        );
        completed += 1;
    }

    println!("completed {completed}, rejected {rejected}");
    if args.flag("status") {
        println!("--- server status ---\n{}", cl.status()?);
    }
    if args.flag("shutdown") {
        println!("server: {}", cl.shutdown_server()?);
    }
    anyhow::ensure!(completed > 0, "no requests completed");
    Ok(())
}
