//! Hardware design-space ablation (DESIGN.md §4 "ablation benches"):
//! sweeps the E2Softmax/AILayerNorm unit parameters the paper fixed —
//! lane count and buffer capacity — and reports area/energy/throughput,
//! showing where the paper's (V=32, L=1024) point sits.
//!
//! ```
//! cargo run --release --offline --example hw_sweep
//! ```

use sole::hw::units::{AiLayerNormUnit, E2SoftmaxUnit, HwUnit, SoftermaxUnit};

fn main() {
    println!("E2Softmax Unit design-space (workload: L=785 rows, DeiT-T@448)\n");
    println!("{:>6} {:>7} {:>12} {:>14} {:>14} {:>12}", "lanes", "l_max", "area um^2",
             "pJ/elem", "Gelem/s", "mW");
    for &lanes in &[8usize, 16, 32, 64] {
        for &l_max in &[512usize, 1024, 2048] {
            let u = E2SoftmaxUnit { lanes, l_max };
            let e = u.energy_per_row(785).total() / 785.0;
            let thr = u.pipeline().throughput(785) / 1e9;
            println!(
                "{:>6} {:>7} {:>12.0} {:>14.3} {:>14.2} {:>12.1}",
                lanes, l_max, u.area().total(), e, thr, u.power_mw(785)
            );
        }
    }

    println!("\nAILayerNorm Unit design-space (workload: C=192)\n");
    println!("{:>6} {:>7} {:>12} {:>14} {:>14} {:>12}", "lanes", "c_max", "area um^2",
             "pJ/elem", "Gelem/s", "mW");
    for &lanes in &[8usize, 16, 32, 64] {
        for &c_max in &[512usize, 1024, 2048] {
            let u = AiLayerNormUnit { lanes, c_max };
            let e = u.energy_per_row(192).total() / 192.0;
            let thr = u.pipeline().throughput(192) / 1e9;
            println!(
                "{:>6} {:>7} {:>12.0} {:>14.3} {:>14.2} {:>12.1}",
                lanes, c_max, u.area().total(), e, thr, u.power_mw(192)
            );
        }
    }

    // intermediate bit-width ablation: what the 4-bit log2 quantization of
    // E2Softmax buys vs Softermax's 16-bit buffer, at matched lanes
    println!("\nBuffer-width ablation (the paper's memory-bound argument):\n");
    let sole = E2SoftmaxUnit::default();
    let soft = SoftermaxUnit::default();
    let es = sole.energy_per_row(1024);
    let eo = soft.energy_per_row(1024);
    println!("SOLE 4-bit buffer:       {:>7.1} pJ/row buffers, {:>7.1} pJ/row compute",
             es.buffers, es.stage1 + es.stage2);
    println!("Softermax 16-bit buffer: {:>7.1} pJ/row buffers, {:>7.1} pJ/row compute",
             eo.buffers, eo.stage1 + eo.stage2);
    println!("buffer-energy ratio: {:.2}x (4-bit vs 16-bit intermediates)",
             eo.buffers / es.buffers);
}
