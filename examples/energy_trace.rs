//! Trace-driven energy simulation: replay a serving trace through the
//! workload model and report what the nonlinear ops would cost on (a) the
//! GPU and (b) 32 SOLE units — the deployment-facing version of Table III.
//!
//! ```
//! cargo run --release --offline --example energy_trace -- \
//!     [--model deit_t] [--requests 512] [--mean-batch 6]
//! ```

use sole::hw::gpu;
use sole::hw::units::{AiLayerNormUnit, E2SoftmaxUnit, HwUnit};
use sole::model::latency::SOLE_UNITS;
use sole::model::PaperModel;
use sole::util::cli::Args;
use sole::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = args.opt_str("model", "deit_t");
    let n_requests = args.opt_usize("requests", 512)?;
    let mean_batch = args.opt_f64("mean-batch", 6.0)?;

    let m = PaperModel::by_name(model).expect("unknown model (see model::PaperModel::zoo)");
    let sm = E2SoftmaxUnit::default();
    let ln = AiLayerNormUnit::default();
    let mut rng = Rng::new(11);

    let (mut gpu_j, mut sole_j, mut gpu_s, mut sole_s) = (0f64, 0f64, 0f64, 0f64);
    let mut served = 0usize;
    while served < n_requests {
        // batch sizes drawn from a geometric-ish arrival mixture
        let b = ((rng.exponential(1.0 / mean_batch)).ceil() as usize).clamp(1, 16);
        served += b;
        for w in m.softmax_work(b) {
            let t = gpu::softmax_time(w.rows, w.len) * w.kernels as f64;
            gpu_j += gpu::energy_j(t);
            gpu_s += t;
            sole_j += sm.energy_j(w.rows, w.len) * w.kernels as f64;
            sole_s += sm.seconds(w.rows, w.len, SOLE_UNITS) * w.kernels as f64;
        }
        for w in m.layernorm_work(b) {
            let t = gpu::layernorm_time(w.rows, w.len) * w.kernels as f64;
            gpu_j += gpu::energy_j(t);
            gpu_s += t;
            sole_j += ln.energy_j(w.rows, w.len) * w.kernels as f64;
            sole_s += ln.seconds(w.rows, w.len, SOLE_UNITS) * w.kernels as f64;
        }
    }
    println!("trace: {served} requests of {model} (mean batch {mean_batch:.1})");
    println!("nonlinear ops on GPU model:   {:>10.2} J   {:>10.1} ms", gpu_j, gpu_s * 1e3);
    println!("nonlinear ops on SOLE units:  {:>10.6} J   {:>10.1} ms", sole_j, sole_s * 1e3);
    println!(
        "energy ratio {:.0}x, time ratio {:.1}x (paper: orders-of-magnitude energy, 36-61x time)",
        gpu_j / sole_j,
        gpu_s / sole_s
    );
    Ok(())
}
