//! End-to-end serving driver (EXPERIMENTS.md §Serving): one
//! `ServiceRouter` process serving the paper's full mixed workload —
//! E2Softmax at L ∈ {49, 128, 785, 1024} plus AILayerNorm at C = 768 —
//! under Poisson arrivals, reporting latency/throughput per service and
//! merged, per offered load.
//!
//! With artifacts present (and the `pjrt` feature) the bucketed
//! `--model/--variant` family joins the mix as an extra service and its
//! top-1 accuracy is reported.  `--queue-cap N` bounds each service's
//! request queue and switches submission to `try_submit`, reporting shed
//! load per service.
//!
//! ```
//! cargo run --release --offline --example serve_loadtest -- \
//!     [--artifacts DIR] [--model deit_t] [--variant fp32_sole] \
//!     [--requests 150] [--rates 8,32,128] [--max-wait-ms 20] \
//!     [--workers 8] [--queue-cap 0]
//! ```

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use sole::coordinator::{
    paper_services, Backend, BatchPolicy, PjrtBackend, ServiceRouter, TrySubmit,
};
use sole::runtime::Engine;
use sole::tensor::Bundle;
use sole::util::cli::Args;
use sole::util::rng::Rng;

/// One service's slice of the mixed workload: pre-generated inputs plus
/// (for the PJRT family) labels for top-1.
struct Lane {
    name: String,
    inputs: Vec<f32>,
    item: usize,
    labels: Option<Vec<i32>>,
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = PathBuf::from(args.opt_str("artifacts", "artifacts"));
    let model = args.opt_str("model", "deit_t");
    let variant = args.opt_str("variant", "fp32_sole");
    let n = args.opt_usize("requests", 150)?;
    let workers = args.opt_usize("workers", 8)?; // total budget over all services
    let queue_cap = match args.opt_usize("queue-cap", 0)? {
        0 => None,
        cap => Some(cap),
    };
    // strict: a typo'd rate is an error naming the flag, not a dropped
    // entry, and a non-positive rate would panic later in the Poisson
    // inter-arrival Duration
    let rates: Vec<f64> = args.opt_list("rates", "8,32,128")?;
    anyhow::ensure!(
        rates.iter().all(|&r| r > 0.0),
        "--rates: rates must be positive, got {rates:?}"
    );
    let max_wait = Duration::from_millis(args.opt_usize("max-wait-ms", 20)? as u64);
    let policy = BatchPolicy { max_wait, max_batch: 16, queue_cap };

    // the mixed paper workload is always served; the PJRT family joins it
    // when artifacts exist AND the build can execute them
    let services = paper_services()?;
    let have_artifacts = dir.join("manifest.json").exists();
    if have_artifacts && !cfg!(feature = "pjrt") {
        println!("artifacts found but built without --features pjrt — software services only");
    }

    // pre-generate each software lane's inputs once (64 normal rows each)
    let mut rng = Rng::new(99);
    let mut lanes: Vec<Lane> = services
        .iter()
        .map(|(name, be)| {
            let item = be.item_input_len();
            let mut inputs = vec![0f32; 64 * item];
            rng.fill_normal(&mut inputs, 0.0, 2.0);
            Lane { name: name.clone(), inputs, item, labels: None }
        })
        .collect();
    // the eval set moves into its lane (it is the largest buffer here);
    // only (name, backend) is kept for per-rate registration
    let pjrt_family = if have_artifacts && cfg!(feature = "pjrt") {
        let engine = Engine::open(&dir)?;
        println!("loading {model}/{variant} buckets ...");
        let be = Arc::new(PjrtBackend::from_family(&engine, model, variant)?);
        let data = Bundle::load(&dir.join("data/cv_eval"))?;
        let name = format!("{model}/{variant}");
        lanes.push(Lane {
            name: name.clone(),
            inputs: data.get("x")?.as_f32()?,
            item: be.item_input_len(),
            labels: Some(data.get("y")?.as_i32()?),
        });
        Some((name, be))
    } else {
        None
    };
    println!(
        "mixed workload: {} services, {workers} total workers, queue_cap {queue_cap:?}",
        lanes.len()
    );

    for &rate in &rates {
        // a fresh router per offered load keeps the metrics per-rate
        let mut builder = ServiceRouter::builder(workers).default_policy(policy.clone());
        for (name, be) in &services {
            builder = builder.service(name, be.clone());
        }
        if let Some((name, be)) = &pjrt_family {
            builder = builder.hot_service(name, be.clone(), 2);
        }
        let router = builder.start()?;
        let cl = router.client();

        let mut rng = Rng::new(7);
        let t0 = Instant::now();
        let mut pending = Vec::new();
        let mut shed = vec![0usize; lanes.len()];
        for i in 0..n {
            let lane_idx = i % lanes.len();
            let lane = &lanes[lane_idx];
            let row = i / lanes.len() % (lane.inputs.len() / lane.item);
            let input = lane.inputs[row * lane.item..(row + 1) * lane.item].to_vec();
            if queue_cap.is_some() {
                match cl.try_submit(&lane.name, input)? {
                    TrySubmit::Accepted(rx) => pending.push((lane_idx, row, rx)),
                    TrySubmit::Full(_) => shed[lane_idx] += 1,
                }
            } else {
                pending.push((lane_idx, row, cl.submit(&lane.name, input)?));
            }
            std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
        }
        let served = pending.len();
        let mut correct = 0usize;
        let mut labeled = 0usize;
        for (lane_idx, row, rx) in pending {
            let r = rx.recv()?;
            if let Some(y) = &lanes[lane_idx].labels {
                let pred = r
                    .output
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                labeled += 1;
                if pred as i32 == y[row] {
                    correct += 1;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        println!("\noffered {rate:.0} req/s: served {served} in {wall:.2}s ({:.1} req/s){}",
            served as f64 / wall,
            if labeled > 0 {
                format!(", top-1 {:.1}%", 100.0 * correct as f64 / labeled as f64)
            } else {
                String::new()
            }
        );
        println!(
            "{:>16} {:>4} {:>10} {:>10} {:>10} {:>10} {:>6}",
            "service", "wrk", "p50 ms", "p99 ms", "mean ms", "avg batch", "shed"
        );
        for (lane_idx, lane) in lanes.iter().enumerate() {
            let m = router.metrics(&lane.name).expect("registered lane");
            let (p50, p99, mean) = m.total_latency();
            println!(
                "{:>16} {:>4} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>6}",
                lane.name,
                router.workers(&lane.name).unwrap_or(0),
                p50 * 1e3,
                p99 * 1e3,
                mean * 1e3,
                m.mean_batch(),
                shed[lane_idx],
            );
        }
        println!("merged: {}", router.merged_summary());
        router.shutdown();
    }
    Ok(())
}
