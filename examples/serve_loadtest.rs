//! End-to-end serving driver (EXPERIMENTS.md §Serving): serves
//! Poisson-arrival requests through the dynamic batcher and reports
//! latency/throughput per offered load.
//!
//! With artifacts present it loads the bucketed deit_t SOLE artifacts
//! (PJRT backend, top-1 accuracy reported); without them it falls back to
//! the bit-exact software E2Softmax op-service so the serving stack is
//! drivable everywhere.  `--queue-cap N` bounds the request queue and
//! switches submission to `try_submit`, reporting shed load.
//!
//! ```
//! cargo run --release --offline --example serve_loadtest -- \
//!     [--artifacts DIR] [--model deit_t] [--variant fp32_sole] \
//!     [--requests 96] [--rates 4,16,64] [--max-wait-ms 20] \
//!     [--workers 1] [--queue-cap 0] [--len 128]
//! ```

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use sole::coordinator::{
    Backend, BatchPolicy, Coordinator, PjrtBackend, SoftwareSoftmaxBackend, TrySubmit,
};
use sole::runtime::Engine;
use sole::tensor::Bundle;
use sole::util::cli::Args;
use sole::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = PathBuf::from(args.opt_str("artifacts", "artifacts"));
    let model = args.opt_str("model", "deit_t");
    let variant = args.opt_str("variant", "fp32_sole");
    let n = args.opt_usize("requests", 96);
    let workers = args.opt_usize("workers", 1);
    let queue_cap = match args.opt_usize("queue-cap", 0) {
        0 => None,
        cap => Some(cap),
    };
    let rates: Vec<f64> = args
        .opt_str("rates", "4,16,64")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let max_wait = Duration::from_millis(args.opt_usize("max-wait-ms", 20) as u64);
    let policy = BatchPolicy { max_wait, max_batch: 16, queue_cap };

    // pick the backend: real artifacts when present AND executable (pjrt
    // feature on), software op-service otherwise (same coordinator, same
    // batcher, same metrics)
    let have_artifacts = dir.join("manifest.json").exists();
    if have_artifacts && !cfg!(feature = "pjrt") {
        println!("artifacts found but built without --features pjrt — using the software backend");
    }
    let (backend, xs, labels): (Arc<dyn Backend>, Vec<f32>, Option<Vec<i32>>) =
        if have_artifacts && cfg!(feature = "pjrt") {
            let engine = Engine::open(&dir)?;
            println!("loading {model}/{variant} buckets ...");
            let be = PjrtBackend::from_family(&engine, model, variant)?;
            let data = Bundle::load(&dir.join("data/cv_eval"))?;
            let xs = data.get("x")?.as_f32()?;
            let y = data.get("y")?.as_i32()?;
            (Arc::new(be) as Arc<dyn Backend>, xs, Some(y))
        } else {
            let l = args.opt_usize("len", 128);
            println!("no artifacts under {} — software E2Softmax rows of {l}", dir.display());
            let mut rng = Rng::new(99);
            let mut xs = vec![0f32; 256 * l];
            rng.fill_normal(&mut xs, 0.0, 2.0);
            let be = SoftwareSoftmaxBackend::new(l, vec![1, 4, 8, 16]);
            (Arc::new(be) as Arc<dyn Backend>, xs, None)
        };
    let item = backend.item_input_len();
    println!("buckets {:?}, item {} f32, workers {workers}, queue_cap {queue_cap:?}", backend.buckets(), item);

    println!(
        "\n{:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>6} {:>8}",
        "rate req/s", "achieved", "p50 ms", "p99 ms", "mean ms", "avg batch", "shed", "top-1"
    );
    for &rate in &rates {
        let co = Coordinator::start(backend.clone(), policy.clone(), workers);
        let cl = co.client();
        let mut rng = Rng::new(7);
        let t0 = Instant::now();
        let mut pending = Vec::new();
        let mut shed = 0usize;
        for i in 0..n {
            let idx = i % (xs.len() / item);
            let input = xs[idx * item..(idx + 1) * item].to_vec();
            if queue_cap.is_some() {
                match cl.try_submit(input)? {
                    TrySubmit::Accepted(rx) => pending.push((idx, rx)),
                    TrySubmit::Full(_) => shed += 1,
                }
            } else {
                pending.push((idx, cl.submit(input)?));
            }
            std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
        }
        let mut correct = 0usize;
        let served = pending.len();
        for (idx, rx) in pending {
            let r = rx.recv()?;
            if let Some(y) = &labels {
                let pred = r
                    .output
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred as i32 == y[idx] {
                    correct += 1;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let (p50, p99, mean) = co.metrics.total_latency();
        let top1 = match &labels {
            Some(_) if served > 0 => format!("{:.1}%", 100.0 * correct as f64 / served as f64),
            _ => "-".to_string(),
        };
        println!(
            "{:>10.1} {:>10.1} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>6} {:>8}",
            rate,
            served as f64 / wall,
            p50 * 1e3,
            p99 * 1e3,
            mean * 1e3,
            co.metrics.mean_batch(),
            shed,
            top1,
        );
        co.shutdown();
    }
    Ok(())
}
