//! End-to-end serving driver (EXPERIMENTS.md §Serving): loads the bucketed
//! deit_t SOLE artifacts, serves Poisson-arrival requests through the
//! dynamic batcher, and reports latency/throughput per offered load.
//!
//! ```
//! cargo run --release --offline --example serve_loadtest -- \
//!     [--artifacts DIR] [--model deit_t] [--variant fp32_sole] \
//!     [--requests 96] [--rates 4,16,64] [--max-wait-ms 20]
//! ```

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use sole::coordinator::{Backend, BatchPolicy, Coordinator, PjrtBackend};
use sole::runtime::Engine;
use sole::tensor::Bundle;
use sole::util::cli::Args;
use sole::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = PathBuf::from(args.opt_str("artifacts", "artifacts"));
    let model = args.opt_str("model", "deit_t");
    let variant = args.opt_str("variant", "fp32_sole");
    let n = args.opt_usize("requests", 96);
    let rates: Vec<f64> = args
        .opt_str("rates", "4,16,64")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let max_wait = Duration::from_millis(args.opt_usize("max-wait-ms", 20) as u64);

    let engine = Engine::open(&dir)?;
    println!("loading {model}/{variant} buckets ...");
    let backend = Arc::new(PjrtBackend::from_family(&engine, model, variant)?);
    let item = backend.item_input_len();
    println!("buckets {:?}, item {} f32", backend.buckets(), item);

    let data = Bundle::load(&dir.join("data/cv_eval"))?;
    let xs = data.get("x")?.as_f32()?;
    let y = data.get("y")?.as_i32()?;

    println!("\n{:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>8}", "rate req/s", "achieved",
             "p50 ms", "p99 ms", "mean ms", "avg batch", "top-1");
    for &rate in &rates {
        let co = Coordinator::start(backend.clone(), BatchPolicy { max_wait, max_batch: 16 }, 1);
        let cl = co.client();
        let mut rng = Rng::new(7);
        let t0 = Instant::now();
        let mut pending = Vec::new();
        for i in 0..n {
            let idx = i % (xs.len() / item);
            pending.push((idx, cl.submit(xs[idx * item..(idx + 1) * item].to_vec())?));
            std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
        }
        let mut correct = 0usize;
        for (idx, rx) in pending {
            let r = rx.recv()?;
            let pred = r
                .output
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as i32 == y[idx] {
                correct += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let (p50, p99, mean) = co.metrics.total_latency();
        println!(
            "{:>10.1} {:>10.1} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>7.1}%",
            rate,
            n as f64 / wall,
            p50 * 1e3,
            p99 * 1e3,
            mean * 1e3,
            co.metrics.mean_batch(),
            100.0 * correct as f64 / n as f64,
        );
        co.shutdown();
    }
    Ok(())
}
