//! Quickstart: load an AOT artifact, run a batch, print predictions.
//!
//! ```
//! cargo run --release --offline --example quickstart -- [--artifacts DIR] \
//!     [--model deit_t] [--variant fp32_sole]
//! ```
//!
//! Demonstrates the minimal API surface: `Engine::open` -> `load` ->
//! `run_f32`, with the eval dataset read through `tensor::Bundle`.

use std::path::PathBuf;

use anyhow::Result;
use sole::runtime::Engine;
use sole::tensor::Bundle;
use sole::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = PathBuf::from(args.opt_str("artifacts", "artifacts"));
    let model = args.opt_str("model", "deit_t");
    let variant = args.opt_str("variant", "fp32_sole");

    let engine = Engine::open(&dir)?;
    println!("platform: {}", engine.platform());
    let ids = engine.find(model, variant);
    anyhow::ensure!(!ids.is_empty(), "no artifacts for {model}/{variant}");
    let id = ids.iter().find(|i| i.ends_with("_b64")).unwrap_or(&ids[0]);
    println!("loading {id} ...");
    let m = engine.load(id)?;

    let data = Bundle::load(&dir.join("data/cv_eval"))?;
    let x = data.get("x")?;
    let y = data.get("y")?.as_i32()?;
    let xs = x.as_f32()?;
    let item: usize = x.shape[1..].iter().product();
    let b = m.batch();
    let ncls = m.meta.output_shape[1];

    let logits = m.run_f32(&xs[..b * item])?;
    let mut correct = 0;
    for i in 0..b {
        let row = &logits[i * ncls..(i + 1) * ncls];
        let pred = row.iter().enumerate().max_by(|a, c| a.1.partial_cmp(c.1).unwrap()).unwrap().0;
        if pred as i32 == y[i] {
            correct += 1;
        }
        if i < 4 {
            println!(
                "sample {i}: label={} pred={pred} logits[..4]={:?}",
                y[i],
                &row[..4.min(ncls)]
            );
        }
    }
    println!("batch accuracy: {correct}/{b}");
    Ok(())
}
